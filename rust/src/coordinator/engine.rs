//! The trait-based engine layer behind `Selection` routing: one uniform
//! apply/revert/counters surface ([`AdapterEngine`]) implemented by both
//! the scatter [`SwitchEngine`] and the incremental fused-mode
//! [`FusionEngine`], plus the [`Router`] — the per-request state machine
//! that drives base / single / set selections onto ONE resident weight
//! store (DESIGN.md §12).
//!
//! ## Why a trait
//!
//! Before this redesign the server forked into per-policy code paths at
//! construction time (`Policy::ShiraScatter` vs `Policy::ShiraFusion`)
//! and fused serving was enabled through `enable_fusion` side channels.
//! Both engines now sit behind [`AdapterEngine`]: the server holds one
//! boxed engine for the single-adapter path, dispatches every apply
//! through the same trait call, and the fused-mode engine joins lazily
//! the first time a `Set` selection arrives.  A custom engine (e.g. a
//! mock, or a future GPU-resident path) drops in by implementing the
//! trait and handing [`Router::with_engine`] a box.
//!
//! ## The routing state machine (DESIGN.md §12.2)
//!
//! The router is in one of three live states — `Base`, `Single` (the
//! switch engine holds an applied adapter + snapshot arena) or `Fused`
//! (the fusion engine holds a non-empty fused set).  Transitions:
//!
//! * single→single runs through the PR 4 one-pass
//!   [`transition_to`](SwitchEngine::transition_to) machinery whenever
//!   the store has the pair plan resident, falling back to revert+apply;
//! * set→set (and single↔set where the single is a roster member) runs
//!   through the PR 4 one-wave merged-support
//!   [`apply_set`](FusionEngine::apply_set) — a single adapter is just a
//!   one-member set, the paper's core claim;
//! * crossing between the engines otherwise goes through base: the
//!   outgoing engine's revert is bit-exact for SHiRA, so the incoming
//!   engine always starts from true base values.
//!
//! Every path lands on bytes bit-identical to serving the same
//! selection from base under the old per-policy servers
//! (property-tested below at 1 and 4 threads).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Instant;

use super::error::ServeError;
use super::fault::FaultInjector;
use super::fusion_engine::{FusionEngine, FusionPlan};
use super::selection::Selection;
use super::store::{AdapterHandle, AdapterStore, AnyAdapter};
use super::switch::{SwitchEngine, SwitchPath};
use crate::adapter::{AdapterTransition, LoraAdapter};
use crate::model::weights::WeightStore;
use crate::util::threadpool::ThreadPool;

/// One engine operation: the selection to make resident, plus whatever
/// the caller (the router) has already resolved for it — store handles
/// for the named adapters and, for single→single switches, the resident
/// pairwise transition plan.
pub struct EngineOp<'a> {
    /// What should be resident after this call.
    pub selection: &'a Selection,
    /// Decoded store handles for the selection's adapters, positional
    /// with [`Selection::names`].  Engines that resolve adapters
    /// themselves (the fusion engine's roster) may be handed an empty
    /// slice.
    pub handles: &'a [Arc<AdapterHandle>],
    /// Resident A→B transition plan for the (currently-active →
    /// incoming) pair, when the store had one.  `None` falls back to
    /// revert+apply; bytes are identical either way.
    pub transition: Option<Arc<AdapterTransition>>,
}

/// Pure-data description of how to put BASE values back on everything an
/// engine currently deviates from base — the engine's half of the
/// router's transactional guard (DESIGN.md §13.1).  Captured BEFORE a
/// mutation dispatches, from engine state that no mutation wave
/// overwrites, so it stays valid even when the wave panics halfway.
pub struct RollbackPlan {
    /// Per target tensor: support indices and the base values to scatter
    /// back onto them (SHiRA state — bit-exact restore).
    pub sparse: Vec<(String, Vec<u32>, Vec<f32>)>,
    /// A dense-fused LoRA adapter whose unfuse must be replayed (after
    /// the router restores the captured pre-images of its targets).
    /// Carries the engine-documented unfuse float drift.
    pub lora: Option<Arc<LoraAdapter>>,
}

/// Cumulative counters an engine reports into the serve summary.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineCounters {
    /// Adapter activations / incremental set updates performed.
    pub applies: u64,
    /// One-pass direct A→B transitions among the applies (switch engine).
    pub direct_transitions: u64,
    /// Store-built shard-plan sets ignored as mismatched (switch engine).
    pub plan_mismatches: u64,
}

/// Uniform apply/revert/report surface over the resident weights — the
/// one interface the server's request loop talks to, implemented by
/// [`SwitchEngine`] and [`FusionEngine`].
///
/// Engines never own the weights: the caller owns ONE resident copy of
/// the base model and passes it into every call, so several engines can
/// cooperate on the same store (the router interleaves them).
///
/// `Send` is a supertrait so a boxed engine — and therefore the
/// [`Router`] that owns it — can move into a fleet replica worker
/// thread (`coordinator::fleet`).  Engines hold only owned state plus
/// `Arc`s of `Sync` substrates (pool, fault injector), so the bound is
/// free for the in-tree implementations.
pub trait AdapterEngine: Send {
    /// Stable name of the engine ("switch" / "fusion") for reports.
    fn kind(&self) -> &'static str;

    /// Make `op.selection` resident on `weights`, transitioning from
    /// whatever this engine currently has applied.  Returns the path the
    /// switch took.
    fn apply(
        &mut self,
        weights: &mut WeightStore,
        op: &EngineOp<'_>,
    ) -> Result<SwitchPath, ServeError>;

    /// Restore base values for everything this engine has applied
    /// (bit-exact for SHiRA state; dense LoRA unfuse leaves float
    /// drift).  A no-op when nothing is applied.
    fn revert(&mut self, weights: &mut WeightStore);

    /// Cumulative counters for the serve summary.
    fn counters(&self) -> EngineCounters;

    /// Rollback description for whatever this engine currently has
    /// applied, or `None` when it deviates nothing from base.  Must read
    /// only state that mutation waves never overwrite (so it is valid to
    /// call this before dispatch and trust it after a mid-wave panic).
    /// Engines that cannot describe a rollback return `None` and forfeit
    /// transactional protection (the default).
    fn rollback(&self) -> Option<RollbackPlan> {
        None
    }

    /// Forget all applied state WITHOUT touching the weights — called by
    /// the router's recovery after it has restored base values itself.
    /// Default: no-op (an engine without rollback support keeps its
    /// state).
    fn clear_applied(&mut self) {}

    /// Arm a deterministic fault injector (chaos tests).  Default: no-op
    /// — engines without fault hooks simply never fire.
    fn set_fault(&mut self, _fault: Arc<FaultInjector>) {}
}

impl AdapterEngine for SwitchEngine {
    fn kind(&self) -> &'static str {
        "switch"
    }

    /// `Base` reverts; `Single` scatters (SHiRA — through the one-pass
    /// transition when `op.transition` is resident) or dense-fuses
    /// (LoRA).  `Set` selections belong to the fusion engine and error.
    fn apply(
        &mut self,
        weights: &mut WeightStore,
        op: &EngineOp<'_>,
    ) -> Result<SwitchPath, ServeError> {
        match op.selection {
            Selection::Base => {
                SwitchEngine::revert(self, weights);
                Ok(SwitchPath::Fallback)
            }
            Selection::Single { name, alpha } => {
                let handle = op
                    .handles
                    .first()
                    .ok_or_else(|| ServeError::UnknownAdapter(name.clone()))?;
                match &handle.adapter {
                    AnyAdapter::Shira(a) => match &op.transition {
                        Some(tp) => {
                            let (_t, path) = self.transition_to(
                                weights,
                                Arc::clone(a),
                                Some(Arc::clone(&handle.plans)),
                                tp,
                                *alpha,
                            );
                            Ok(path)
                        }
                        None => {
                            self.switch_to_shira_planned(
                                weights,
                                Arc::clone(a),
                                Some(Arc::clone(&handle.plans)),
                                *alpha,
                            );
                            Ok(SwitchPath::Fallback)
                        }
                    },
                    AnyAdapter::ShiraF16(a) => {
                        // f16-resident singles always revert+apply: the
                        // one-pass transition machinery is f32-active-only,
                        // so a resident pair plan is deliberately ignored
                        // (DESIGN.md §15.4).  Bytes are identical either
                        // way — binary16 → f32 widening is exact.
                        self.switch_to_shira_f16(
                            weights,
                            Arc::clone(a),
                            Some(Arc::clone(&handle.plans)),
                            *alpha,
                        );
                        Ok(SwitchPath::Fallback)
                    }
                    AnyAdapter::Lora(a) => {
                        // LoRA strength is baked into the adapter's own
                        // scale; the selection alpha is ignored.
                        self.switch_to_lora_shared(weights, Arc::clone(a));
                        Ok(SwitchPath::Fallback)
                    }
                }
            }
            Selection::Set { .. } => Err(ServeError::InvalidSelection {
                spec: op.selection.key(),
                reason: "set selections route to the fusion engine".into(),
            }),
            Selection::Auto => Err(ServeError::Gate {
                reason: "unresolved auto selection reached the switch engine \
                         (the front end must gate-resolve it first)"
                    .into(),
            }),
        }
    }

    fn revert(&mut self, weights: &mut WeightStore) {
        SwitchEngine::revert(self, weights);
    }

    fn counters(&self) -> EngineCounters {
        EngineCounters {
            applies: self.switches,
            direct_transitions: self.transitions,
            plan_mismatches: self.plan_mismatches,
        }
    }

    /// SHiRA state rolls back by scattering the arena's base snapshot;
    /// LoRA state by replaying the dense unfuse over restored pre-images.
    fn rollback(&self) -> Option<RollbackPlan> {
        if let Some(sparse) = self.shira_rollback() {
            return Some(RollbackPlan { sparse, lora: None });
        }
        self.lora_rollback().map(|lora| RollbackPlan {
            sparse: Vec::new(),
            lora: Some(lora),
        })
    }

    fn clear_applied(&mut self) {
        self.clear_active();
    }

    fn set_fault(&mut self, fault: Arc<FaultInjector>) {
        SwitchEngine::set_fault(self, fault);
    }
}

impl AdapterEngine for FusionEngine {
    fn kind(&self) -> &'static str {
        "fusion"
    }

    /// Every selection is a fused-set transition: `Base` empties the set,
    /// `Single` is a one-member set (the paper's claim made literal) and
    /// `Set` is the general case — all one merged-support wave via
    /// [`FusionEngine::apply_set`].  Members must be in this engine's
    /// roster; the router guarantees that by (re)building the plan before
    /// dispatching here.
    fn apply(
        &mut self,
        weights: &mut WeightStore,
        op: &EngineOp<'_>,
    ) -> Result<SwitchPath, ServeError> {
        let one;
        let desired: &[(String, f32)] = match op.selection {
            Selection::Base => &[],
            Selection::Single { name, alpha } => {
                one = [(name.clone(), *alpha)];
                &one
            }
            Selection::Set { members } => members,
            Selection::Auto => {
                return Err(ServeError::Gate {
                    reason: "unresolved auto selection reached the fusion \
                             engine (the front end must gate-resolve it first)"
                        .into(),
                })
            }
        };
        self.apply_set(weights, desired)?;
        Ok(SwitchPath::Fused)
    }

    fn revert(&mut self, weights: &mut WeightStore) {
        if self.is_active() {
            // Emptying the set restores base values on the union exactly;
            // the engine stays active so the snapshot is reusable.
            self.apply_set(weights, &[])
                .expect("empty set over an active engine cannot fail");
        }
    }

    fn counters(&self) -> EngineCounters {
        EngineCounters {
            applies: self.updates(),
            direct_transitions: 0,
            plan_mismatches: 0,
        }
    }

    /// An activated engine rolls back by scattering `base_snap` over the
    /// whole union support — base values captured at activation time,
    /// never overwritten by refresh waves.
    fn rollback(&self) -> Option<RollbackPlan> {
        self.snapshot_parts()
            .map(|sparse| RollbackPlan { sparse, lora: None })
    }

    fn clear_applied(&mut self) {
        self.clear_active();
    }

    fn set_fault(&mut self, fault: Arc<FaultInjector>) {
        FusionEngine::set_fault(self, fault);
    }
}

/// What one [`Router::apply`] did.
#[derive(Clone, Debug, Default)]
pub struct Applied {
    /// Did the resident weights change selection (false when the request
    /// repeats the active selection)?
    pub switched: bool,
    /// The path the apply took, when an engine ran.
    pub path: Option<SwitchPath>,
    /// Microseconds of weight mutation (engine reverts + applies) this
    /// call performed — store fetch/decode and roster (re)builds are
    /// deliberately excluded, so the serving `switch_us` metric keeps
    /// its historical meaning (pure switch cost, not cache misses).
    pub switch_us: f64,
    /// Set when the selection is an unfused-mode LoRA adapter: the
    /// weights stay at base and the caller threads this adapter's
    /// branches through the forward pass instead.
    pub unfused_lora: Option<Arc<LoraAdapter>>,
}

impl Applied {
    fn unchanged() -> Applied {
        Applied::default()
    }
}

/// Which engine currently deviates the weights from base.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Live {
    Base,
    Single,
    Fused,
}

/// Pre-mutation capture of everything one [`Router::apply`] could
/// clobber — the write-ahead half of the transactional switch guard
/// (DESIGN.md §13.1).  Captures run lazily at the first-mutation choke
/// point of each apply arm (affinity fast paths never pay for them); on
/// failure [`Router`] recovery replays them in a fixed order that lands
/// every touched slot back on base values.
#[derive(Default)]
struct WeightTxn {
    /// Sparse pre-images of the incoming selection's support, captured
    /// from the live weights before any wave ran.  Overlap slots still
    /// hold the OUTGOING adapter's contributions, so recovery restores
    /// these first and lets the base scatters below overwrite them.
    incoming: Vec<(String, Vec<u32>, Vec<f32>)>,
    /// Dense pre-images of whole target tensors (LoRA targets, incoming
    /// or outgoing) — restored before everything else.
    dense: Vec<(String, Vec<f32>)>,
    /// Outgoing single-engine rollback: base values at the active
    /// adapter's support, or the LoRA adapter whose unfuse to replay.
    single_out: Option<RollbackPlan>,
    /// Outgoing fused-engine rollback: base values at the union support.
    fused_out: Option<RollbackPlan>,
    /// The REBUILT fusion engine's base snapshot, captured when
    /// `ensure_roster` replaced the plan mid-apply (covers slots of the
    /// new union that the old plans never knew).
    rebuilt: Option<Vec<(String, Vec<u32>, Vec<f32>)>>,
    /// True once the outgoing state has been captured — i.e. the apply
    /// arm reached its first weight mutation.
    outgoing_captured: bool,
    /// True once an engine apply was dispatched on the weights: an `Err`
    /// after this point is a mutation failure and recovers; pre-dispatch
    /// errors (validate, fetch, quarantine, roster build) pass through
    /// untouched, preserving the legacy error semantics.
    dispatched: bool,
}

impl WeightTxn {
    /// Record sparse/dense pre-images of the incoming selection's
    /// support, read from the live weights (call before any wave runs).
    fn capture_incoming(&mut self, w: &WeightStore, handle: &AdapterHandle) {
        match &handle.adapter {
            AnyAdapter::Shira(a) => {
                for (target, delta) in &a.tensors {
                    self.incoming.push((
                        target.clone(),
                        delta.idx.clone(),
                        w.gather(target, &delta.idx),
                    ));
                }
            }
            AnyAdapter::ShiraF16(a) => {
                for (target, delta) in &a.tensors {
                    self.incoming.push((
                        target.clone(),
                        delta.idx.clone(),
                        w.gather(target, &delta.idx),
                    ));
                }
            }
            AnyAdapter::Lora(a) => {
                for lt in &a.tensors {
                    self.dense
                        .push((lt.target.clone(), w.get(&lt.target).data.clone()));
                }
            }
        }
    }
}

/// Stringify a caught panic payload for [`ServeError::MutationRolledBack`].
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// The per-request routing state machine: owns the resident weights,
/// the boxed single-adapter engine, and the lazily-built fused-mode
/// engine, and drives any interleaving of base / single / set
/// selections onto them (module docs; DESIGN.md §12.2).
///
/// The router also owns the residency bookkeeping the old server did
/// through side channels: the active single adapter and the whole
/// fusion roster stay pinned in the store, so cache pressure can never
/// evict an adapter an in-flight apply may touch.
pub struct Router {
    weights: WeightStore,
    /// The single-adapter path (normally a [`SwitchEngine`]), behind the
    /// trait so alternative engines can drop in.
    single: Box<dyn AdapterEngine>,
    /// The fused-set path; built on the first `Set` selection and
    /// rebuilt whenever a set names adapters outside the roster.
    fused: Option<FusionEngine>,
    pool: Option<Arc<ThreadPool>>,
    live: Live,
    /// Canonical key of the applied selection.
    active: Option<String>,
    /// Name of the adapter the single engine holds (for pair-plan
    /// lookups and pin bookkeeping).
    single_name: Option<String>,
    pinned_active: Option<String>,
    pinned_roster: Vec<String>,
    /// Serve LoRA singles unfused (branches on the forward pass) instead
    /// of dense-fusing them into the weights.
    lora_unfused: bool,
    /// Failed mutations rolled back to base by the transactional guard.
    rollbacks: u64,
    /// Deterministic fault injector, forwarded into every engine this
    /// router builds (chaos tests).
    fault: Option<Arc<FaultInjector>>,
    /// A `begin_transition` the store has open for an in-flight
    /// single→single switch; recovery must close it so the plan's
    /// refcount cannot leak when the dispatch dies.
    inflight_plan: Option<(String, String)>,
}

impl Router {
    /// Router over `weights` with a [`SwitchEngine`] single path sharing
    /// `pool` (also used for fused-plan dispatch when sets arrive).
    pub fn new(weights: WeightStore, pool: Option<Arc<ThreadPool>>, lora_unfused: bool) -> Router {
        let engine: Box<dyn AdapterEngine> =
            Box::new(SwitchEngine::with_pool(pool.clone()));
        Self::with_engine(weights, engine, pool, lora_unfused)
    }

    /// Router with a custom boxed single-adapter engine.
    pub fn with_engine(
        weights: WeightStore,
        single: Box<dyn AdapterEngine>,
        pool: Option<Arc<ThreadPool>>,
        lora_unfused: bool,
    ) -> Router {
        Router {
            weights,
            single,
            fused: None,
            pool,
            live: Live::Base,
            active: None,
            single_name: None,
            pinned_active: None,
            pinned_roster: Vec::new(),
            lora_unfused,
            rollbacks: 0,
            fault: None,
            inflight_plan: None,
        }
    }

    /// Failed mutations this router has rolled back to base.
    pub fn rollbacks(&self) -> u64 {
        self.rollbacks
    }

    /// Arm a deterministic fault injector on this router's engines — the
    /// current single engine, any live fused engine, and every fused
    /// engine built later.
    pub fn set_fault(&mut self, fault: Arc<FaultInjector>) {
        self.single.set_fault(Arc::clone(&fault));
        if let Some(f) = &mut self.fused {
            AdapterEngine::set_fault(f, Arc::clone(&fault));
        }
        self.fault = Some(fault);
    }

    /// The resident weights.
    pub fn weights(&self) -> &WeightStore {
        &self.weights
    }

    /// Canonical key of the currently-applied selection (the batcher's
    /// affinity target).  `None` before the first apply.
    pub fn active_key(&self) -> Option<&str> {
        self.active.as_deref()
    }

    /// Name of the single adapter the switch path currently holds, when
    /// the router is live in single mode — the `from` side a pairwise
    /// transition plan would depart from.  `None` in base/fused mode, so
    /// the fleet's affinity ladder only probes plan residency for
    /// replicas that could actually take the one-pass path.
    pub fn active_single(&self) -> Option<&str> {
        if self.live == Live::Single {
            self.single_name.as_deref()
        } else {
            None
        }
    }

    /// The fused-mode engine, once a `Set` selection has built it.
    pub fn fusion(&self) -> Option<&FusionEngine> {
        self.fused.as_ref()
    }

    /// Counters of the single-adapter engine (transitions, mismatches).
    pub fn single_counters(&self) -> EngineCounters {
        self.single.counters()
    }

    /// Counters of the fused-mode engine (incremental updates), zeroed
    /// when no set has arrived yet.
    pub fn fused_counters(&self) -> EngineCounters {
        self.fused
            .as_ref()
            .map(|f| f.counters())
            .unwrap_or_default()
    }

    /// Make `sel` resident, fetching whatever it names from `store` and
    /// picking the cheapest machinery for the transition (module docs).
    /// Repeating the active selection is free (except unfused-LoRA
    /// selections, which re-surface their adapter every call so each
    /// batch can thread the branches through the forward pass).
    ///
    /// Every apply runs inside a weight transaction (DESIGN.md §13.1):
    /// pre-images of everything the arm will touch are captured right
    /// before its first mutation, and a panic out of any engine wave —
    /// or an engine error after dispatch — rolls the resident weights
    /// back to base, releases every pin the apply took, and surfaces
    /// [`ServeError::MutationRolledBack`] (panics) or the original error
    /// (post-dispatch `Err`s).  Pre-dispatch errors (validation, store
    /// fetch, quarantine, roster build) never mutated the weights and
    /// pass through untouched.
    pub fn apply(
        &mut self,
        store: &mut AdapterStore,
        sel: &Selection,
    ) -> Result<Applied, ServeError> {
        sel.validate()?;
        let mut txn = WeightTxn::default();
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            self.apply_guarded(store, sel, &mut txn)
        }));
        match outcome {
            Ok(Ok(applied)) => Ok(applied),
            Ok(Err(e)) => {
                if txn.dispatched {
                    self.recover(store, &mut txn);
                }
                Err(e)
            }
            Err(payload) => {
                self.recover(store, &mut txn);
                Err(ServeError::MutationRolledBack {
                    selection: sel.key(),
                    cause: panic_message(payload),
                })
            }
        }
    }

    /// The routing state machine proper — [`Self::apply`] without the
    /// transactional wrapper.  Each arm records pre-images into `txn` at
    /// the choke point right before its first weight mutation (the
    /// affinity fast paths above those points never pay for a capture).
    fn apply_guarded(
        &mut self,
        store: &mut AdapterStore,
        sel: &Selection,
        txn: &mut WeightTxn,
    ) -> Result<Applied, ServeError> {
        let key = sel.key();
        let same = self.active.as_deref() == Some(key.as_str());
        match sel {
            Selection::Base => {
                let switched = self.live != Live::Base;
                let t0 = Instant::now();
                if switched {
                    self.capture_outgoing(txn);
                    self.to_base(store);
                }
                self.active = Some(key);
                Ok(Applied {
                    switched,
                    path: None,
                    switch_us: t0.elapsed().as_secs_f64() * 1e6,
                    unfused_lora: None,
                })
            }
            Selection::Single { name, .. } => {
                // Affinity fast path: a repeated selection touches neither
                // the store nor the engines.  (Unfused-LoRA mode must
                // re-surface its adapter every call, so it fetches first.)
                if same && !self.lora_unfused {
                    return Ok(Applied::unchanged());
                }
                let handle = store.fetch(name)?;
                if self.lora_unfused {
                    if let AnyAdapter::Lora(a) = &handle.adapter {
                        // Unfused mode: weights stay at base, branches ride
                        // the forward pass.  Re-surfaced every call.
                        let switched = !same;
                        let t0 = Instant::now();
                        if self.live != Live::Base {
                            self.capture_outgoing(txn);
                            self.to_base(store);
                        }
                        self.active = Some(key);
                        return Ok(Applied {
                            switched,
                            path: None,
                            switch_us: t0.elapsed().as_secs_f64() * 1e6,
                            unfused_lora: Some(Arc::clone(a)),
                        });
                    }
                }
                if same {
                    return Ok(Applied::unchanged());
                }
                // A SHiRA single that is already a member of a live fused
                // roster is served AS a one-member set: single↔set moves
                // become one merged-support wave instead of a
                // revert + activate round-trip.
                if matches!(
                    &handle.adapter,
                    AnyAdapter::Shira(_) | AnyAdapter::ShiraF16(_)
                ) {
                    let member = self
                        .fused
                        .as_ref()
                        .map(|f| f.is_active() && f.plan().member_index(name).is_some())
                        .unwrap_or(false);
                    if member {
                        let t0 = Instant::now();
                        // The roster member's support is inside the fused
                        // union, so the fused snapshot below covers the
                        // incoming slots too — no separate incoming capture.
                        self.capture_outgoing(txn);
                        if self.live == Live::Single {
                            self.single.revert(&mut self.weights);
                            self.release_single(store);
                            self.live = Live::Base;
                            // Keep `active` truthful at every state change
                            // so an error below cannot leave a stale key.
                            self.active = Some(String::new());
                        }
                        let op = EngineOp {
                            selection: sel,
                            handles: &[],
                            transition: None,
                        };
                        let f = self.fused.as_mut().expect("checked above");
                        let path = f.apply(&mut self.weights, &op)?;
                        self.live = Live::Fused;
                        self.active = Some(key);
                        return Ok(Applied {
                            switched: true,
                            path: Some(path),
                            switch_us: t0.elapsed().as_secs_f64() * 1e6,
                            unfused_lora: None,
                        });
                    }
                }
                // Switch-engine path.  Empty a live fused set first so the
                // engine starts from true base values.
                let t0 = Instant::now();
                txn.capture_incoming(&self.weights, &handle);
                self.capture_outgoing(txn);
                if self.live == Live::Fused {
                    if let Some(f) = &mut self.fused {
                        AdapterEngine::revert(f, &mut self.weights);
                    }
                    self.live = Live::Base;
                    self.active = Some(String::new());
                }
                // Pin the incoming adapter before the apply; the previous
                // active adapter's pin is released after.  An in-flight
                // switch can therefore never lose its cache entry.
                store.pin(name);
                if let Some(prev) = self.pinned_active.replace(name.clone()) {
                    if prev != *name {
                        store.unpin(&prev);
                    }
                }
                // Hot pair with a resident pairwise plan: one pass over
                // the A∪B union, one dispatch wave.  Cold pair (or no
                // previous single): revert+apply.  Bytes identical.
                let prev = self
                    .single_name
                    .take()
                    .filter(|p| self.live == Live::Single && p != name);
                let transition = prev
                    .as_deref()
                    .and_then(|p| store.begin_transition(p, name));
                let op = EngineOp {
                    selection: sel,
                    handles: std::slice::from_ref(&handle),
                    transition,
                };
                if op.transition.is_some() {
                    // Track the open transition so recovery can close it
                    // if the dispatch below dies.
                    self.inflight_plan =
                        Some((prev.clone().unwrap_or_default(), name.clone()));
                }
                // Past this point an `Err` means the engine touched the
                // weights: route it through recovery.
                txn.dispatched = true;
                let path = self.single.apply(&mut self.weights, &op)?;
                if let Some((from, to)) = self.inflight_plan.take() {
                    store.end_transition(&from, &to);
                }
                self.live = Live::Single;
                self.single_name = Some(name.clone());
                self.active = Some(key);
                Ok(Applied {
                    switched: true,
                    path: Some(path),
                    switch_us: t0.elapsed().as_secs_f64() * 1e6,
                    unfused_lora: None,
                })
            }
            Selection::Set { members } => {
                if same {
                    return Ok(Applied::unchanged());
                }
                // The fused set is built from base: revert any single
                // first (bit-exact for SHiRA).  `active` tracks every
                // intermediate state so a failed roster build below can
                // never leave a stale key claiming the single is still
                // resident.
                let revert_t0 = Instant::now();
                self.capture_outgoing(txn);
                if self.live == Live::Single {
                    self.single.revert(&mut self.weights);
                    self.release_single(store);
                    self.live = Live::Base;
                    self.active = Some(String::new());
                }
                let revert_us = revert_t0.elapsed().as_secs_f64() * 1e6;
                // Roster (re)builds are lifecycle cost, not switch cost:
                // excluded from the timed window like the store fetch.
                let rebuilt = self.ensure_roster(store, members)?;
                if rebuilt {
                    // A rebuilt plan's union may cover slots the captured
                    // outgoing snapshots never knew; snapshot it so a
                    // failed activate wave below restores the NEW union.
                    txn.rebuilt = self.fused.as_ref().and_then(|f| f.snapshot_parts());
                }
                let op = EngineOp {
                    selection: sel,
                    handles: &[],
                    transition: None,
                };
                let t0 = Instant::now();
                let f = self.fused.as_mut().expect("ensure_roster built it");
                let path = f.apply(&mut self.weights, &op)?;
                self.live = Live::Fused;
                self.active = Some(key);
                Ok(Applied {
                    switched: true,
                    path: Some(path),
                    switch_us: revert_us + t0.elapsed().as_secs_f64() * 1e6,
                    unfused_lora: None,
                })
            }
            Selection::Auto => Err(ServeError::Gate {
                reason: "unresolved auto selection reached the router (the \
                         front end must gate-resolve it first)"
                    .into(),
            }),
        }
    }

    /// Restore base weights exactly and release every pin; drops the
    /// fused-mode engine (the roster shrinks to nothing).  The next set
    /// selection rebuilds it.
    pub fn revert_all(&mut self, store: &mut AdapterStore) {
        self.unpin_roster(store);
        if let Some(mut f) = self.fused.take() {
            f.deactivate(&mut self.weights);
        }
        self.single.revert(&mut self.weights);
        self.release_single(store);
        self.live = Live::Base;
        self.active = None;
    }

    fn to_base(&mut self, store: &mut AdapterStore) {
        match self.live {
            Live::Base => {}
            Live::Single => {
                self.single.revert(&mut self.weights);
                self.release_single(store);
            }
            Live::Fused => {
                if let Some(f) = &mut self.fused {
                    AdapterEngine::revert(f, &mut self.weights);
                }
            }
        }
        self.live = Live::Base;
    }

    /// Capture the outgoing engines' rollback state into `txn` — called
    /// at the choke point right before an apply arm's first weight
    /// mutation (idempotent; later calls are no-ops).  Also records
    /// dense pre-images of any outgoing LoRA's targets so the unfuse
    /// replay during recovery starts from the exact bytes the engine's
    /// own revert would have seen.
    fn capture_outgoing(&self, txn: &mut WeightTxn) {
        if txn.outgoing_captured {
            return;
        }
        txn.outgoing_captured = true;
        txn.single_out = self.single.rollback();
        txn.fused_out = self.fused.as_ref().and_then(|f| AdapterEngine::rollback(f));
        if let Some(plan) = &txn.single_out {
            if let Some(lora) = &plan.lora {
                for lt in &lora.tensors {
                    txn.dense.push((
                        lt.target.clone(),
                        self.weights.get(&lt.target).data.clone(),
                    ));
                }
            }
        }
    }

    /// Put the resident weights back on base values and the router back
    /// in a truthful `Base` state after a failed mutation (DESIGN.md
    /// §13.1).  Restore order matters:
    ///
    /// 1. dense pre-images (LoRA targets) — whole-tensor restore;
    /// 2. the incoming selection's sparse pre-images — overlap slots
    ///    return to their pre-dispatch (outgoing-adapter) values;
    /// 3. outgoing LoRA unfuse replay over the restored pre-images
    ///    (engine-documented float drift, same class as a normal revert);
    /// 4. base scatters LAST — outgoing single, outgoing fused, and any
    ///    rebuilt fusion snapshot — so every slot an engine deviated
    ///    lands on true base bytes (bit-exact for pure-SHiRA state).
    ///
    /// The engines then forget their applied state without touching the
    /// weights, every pin and in-flight transition this apply held is
    /// released, and the active key becomes the base key — truthful,
    /// because base really is resident again.
    fn recover(&mut self, store: &mut AdapterStore, txn: &mut WeightTxn) {
        // A panic before the arm's choke point means nothing has mutated
        // yet and the engines' rollback state is still current: capture
        // it now so the scatters below restore rather than corrupt.
        self.capture_outgoing(txn);
        for (name, vals) in &txn.dense {
            self.weights.get_mut(name).data.copy_from_slice(vals);
        }
        for (name, idx, vals) in &txn.incoming {
            self.weights.scatter(name, idx, vals);
        }
        if let Some(plan) = &txn.single_out {
            if let Some(lora) = &plan.lora {
                for lt in &lora.tensors {
                    self.weights
                        .get_mut(&lt.target)
                        .sub_outer_product(&lt.a, &lt.b, lora.scale);
                }
            }
        }
        for plan in [txn.single_out.as_ref(), txn.fused_out.as_ref()]
            .into_iter()
            .flatten()
        {
            for (name, idx, vals) in &plan.sparse {
                self.weights.scatter(name, idx, vals);
            }
        }
        if let Some(parts) = &txn.rebuilt {
            for (name, idx, vals) in parts {
                self.weights.scatter(name, idx, vals);
            }
        }
        self.single.clear_applied();
        self.fused = None;
        if let Some((from, to)) = self.inflight_plan.take() {
            store.end_transition(&from, &to);
        }
        self.unpin_roster(store);
        self.release_single(store);
        self.live = Live::Base;
        self.active = Some(String::new());
        self.rollbacks += 1;
    }

    fn release_single(&mut self, store: &mut AdapterStore) {
        self.single_name = None;
        if let Some(prev) = self.pinned_active.take() {
            store.unpin(&prev);
        }
    }

    fn unpin_roster(&mut self, store: &mut AdapterStore) {
        for n in self.pinned_roster.drain(..) {
            store.unpin(&n);
        }
    }

    /// Grow (or build) the fusion roster so it covers `members`.
    /// Existing roster members are kept so earlier sets stay addressable
    /// without a rebuild; rosters only shrink via [`Self::revert_all`].
    fn ensure_roster(
        &mut self,
        store: &mut AdapterStore,
        members: &[(String, f32)],
    ) -> Result<bool, ServeError> {
        let covered = match &self.fused {
            None => false,
            Some(f) => members
                .iter()
                .all(|(n, _)| f.plan().member_index(n).is_some()),
        };
        if covered {
            return Ok(false);
        }
        let mut names: Vec<String> = members.iter().map(|(n, _)| n.clone()).collect();
        if let Some(f) = &self.fused {
            for a in f.plan().roster() {
                if !names.iter().any(|x| x == &a.name) {
                    names.push(a.name.clone());
                }
            }
        }
        names.sort();
        names.dedup();
        // Release the previous roster's pins up front: the fetch loop
        // below pins each new member the moment it lands, and stale pins
        // must neither crowd the new members out of the cache nor leak
        // when the rosters are disjoint.
        self.unpin_roster(store);
        let result = self.build_fusion(store, &names);
        if result.is_err() {
            // Don't leave a half-built roster pinned.
            self.unpin_roster(store);
        }
        result.map(|_| true)
    }

    fn build_fusion(
        &mut self,
        store: &mut AdapterStore,
        names: &[String],
    ) -> Result<(), ServeError> {
        let mut roster = Vec::with_capacity(names.len());
        for n in names {
            if n.contains('+') || n.contains('@') {
                // '+' and '@' are selection metacharacters: such a name
                // could never be addressed by a set selection.
                return Err(ServeError::InvalidSelection {
                    spec: n.clone(),
                    reason: "roster member name contains a selection metacharacter ('+' or '@')"
                        .into(),
                });
            }
            match &store.fetch(n)?.adapter {
                AnyAdapter::Shira(a) => {
                    roster.push(Arc::clone(a));
                    // Pin as fetched, so a later member's decode can
                    // never evict this one mid-build (pin only fails for
                    // oversized-uncached entries, which were never
                    // resident to protect).
                    if store.pin(n) {
                        self.pinned_roster.push(n.clone());
                    }
                }
                AnyAdapter::ShiraF16(a) => {
                    // Fused-mode rosters are f32: materialize the exact
                    // f32 values (binary16 → f32 widening is lossless),
                    // so fused bytes match f32-resident serving bit-for-bit.
                    roster.push(Arc::new(a.to_shira()));
                    if store.pin(n) {
                        self.pinned_roster.push(n.clone());
                    }
                }
                AnyAdapter::Lora(_) => return Err(ServeError::NotShira(n.clone())),
            }
        }
        // Unwind any previous fused state BEFORE snapshotting: a live
        // engine's writes are invisible to `revert`, and dropping it
        // without deactivating would bake its deltas into the new base.
        if let Some(mut f) = self.fused.take() {
            f.deactivate(&mut self.weights);
        }
        self.single.revert(&mut self.weights);
        self.release_single(store);
        self.live = Live::Base;
        // The weights are at base now; record that before the fallible
        // plan build/activate so an error cannot leave a stale key.
        self.active = Some(String::new());
        let plan = FusionPlan::build(roster)?;
        let mut fusion = FusionEngine::with_pool(plan, self.pool.clone());
        if let Some(fault) = &self.fault {
            FusionEngine::set_fault(&mut fusion, Arc::clone(fault));
        }
        fusion.activate(&mut self.weights)?;
        self.fused = Some(fusion);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::sparse::SparseDelta;
    use crate::adapter::ShiraAdapter;
    use crate::coordinator::fault::FaultPlan;
    use crate::coordinator::fusion::fuse_shira;
    use crate::coordinator::store::StoreConfig;
    use crate::util::proptest as pt;
    use crate::util::rng::Rng;

    const DIM: usize = 64;

    fn base_weights(seed: u64) -> WeightStore {
        WeightStore::init(
            &[("wq".into(), vec![DIM, DIM]), ("wk".into(), vec![DIM, DIM])],
            seed,
        )
    }

    fn make_adapter(rng: &mut Rng, name: &str, k: usize) -> ShiraAdapter {
        let mk = |rng: &mut Rng| {
            let idx = rng.sample_indices(DIM * DIM, k);
            let mut d = vec![0.0; k];
            rng.fill_normal(&mut d, 0.0, 0.5);
            SparseDelta::new(DIM, DIM, idx, d)
        };
        ShiraAdapter {
            name: name.into(),
            strategy: "rand".into(),
            tensors: vec![("wq".into(), mk(rng)), ("wk".into(), mk(rng))],
        }
    }

    fn adapters(k: usize) -> Vec<ShiraAdapter> {
        let mut rng = Rng::new(0xE1);
        (0..3)
            .map(|i| make_adapter(&mut rng, &format!("ad{i}"), k))
            .collect()
    }

    fn store_with(adapters: &[ShiraAdapter], pool: Option<Arc<ThreadPool>>) -> AdapterStore {
        let mut store = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: 64 << 20,
                prefetch_depth: 4,
                ..StoreConfig::default()
            },
            pool,
        );
        for a in adapters {
            store.add_shira(a);
        }
        store
    }

    fn scaled(a: &ShiraAdapter, w: f32) -> ShiraAdapter {
        ShiraAdapter {
            name: a.name.clone(),
            strategy: a.strategy.clone(),
            tensors: a
                .tensors
                .iter()
                .map(|(t, d)| (t.clone(), d.scaled(w)))
                .collect(),
        }
    }

    /// The per-policy reference the acceptance criterion names: what the
    /// PR 4 servers would make resident for this selection starting from
    /// base — a scatter apply for singles, a serial `fuse_shira` rebuild
    /// of the scaled members (sorted by name, the roster order) for sets.
    fn reference_weights(
        base: &WeightStore,
        zoo: &[ShiraAdapter],
        sel: &Selection,
    ) -> WeightStore {
        let by_name = |n: &str| zoo.iter().find(|a| a.name == n).expect("known adapter");
        match sel {
            Selection::Base => base.clone(),
            Selection::Single { name, alpha } => {
                let mut w = base.clone();
                for (t, d) in &by_name(name).tensors {
                    d.apply(w.get_mut(t), *alpha);
                }
                w
            }
            Selection::Set { members } => {
                let mut sorted = members.clone();
                sorted.sort_by(|a, b| a.0.cmp(&b.0));
                let scaled_members: Vec<ShiraAdapter> = sorted
                    .iter()
                    .map(|(n, w)| scaled(by_name(n), *w))
                    .collect();
                let refs: Vec<&ShiraAdapter> = scaled_members.iter().collect();
                let fused = fuse_shira(&refs, "reference").expect("same target sets");
                let mut w = base.clone();
                for (t, d) in &fused.tensors {
                    d.apply(w.get_mut(t), 1.0);
                }
                w
            }
            Selection::Auto => {
                unreachable!("engine tests never dispatch unresolved autos")
            }
        }
    }

    #[test]
    fn boxed_switch_engine_serves_singles_and_rejects_sets() {
        let zoo = adapters(40);
        let base = base_weights(3);
        let mut store = store_with(&zoo, None);
        let mut weights = base.clone();
        let mut eng: Box<dyn AdapterEngine> = Box::new(SwitchEngine::new());
        let sel = Selection::single_at("ad0", 0.8);
        let h = store.fetch("ad0").unwrap();
        let op = EngineOp {
            selection: &sel,
            handles: std::slice::from_ref(&h),
            transition: None,
        };
        let path = eng.apply(&mut weights, &op).unwrap();
        assert_eq!(path, SwitchPath::Fallback);
        assert!(weights.bit_equal(&reference_weights(&base, &zoo, &sel)));
        assert_eq!(eng.kind(), "switch");
        assert_eq!(eng.counters().applies, 1);
        // Sets are the fusion engine's job.
        let set = Selection::set(&[("ad0", 1.0), ("ad1", 1.0)]);
        let op = EngineOp {
            selection: &set,
            handles: &[],
            transition: None,
        };
        assert!(matches!(
            eng.apply(&mut weights, &op),
            Err(ServeError::InvalidSelection { .. })
        ));
        // Base reverts exactly.
        let op = EngineOp {
            selection: &Selection::Base,
            handles: &[],
            transition: None,
        };
        eng.apply(&mut weights, &op).unwrap();
        assert!(weights.bit_equal(&base));
    }

    #[test]
    fn fusion_engine_serves_singles_as_one_member_sets() {
        // The paper's claim made literal: through the trait, a Single on
        // the fusion engine is a one-member set — and bit-identical to
        // the scatter path serving the same single.
        let zoo = adapters(40);
        let base = base_weights(5);
        let roster: Vec<Arc<ShiraAdapter>> =
            zoo.iter().map(|a| Arc::new(a.clone())).collect();
        let plan = FusionPlan::build(roster).unwrap();
        let mut f = FusionEngine::new(plan);
        let mut weights = base.clone();
        f.activate(&mut weights).unwrap();
        for sel in [
            Selection::single_at("ad1", 0.7),
            Selection::single("ad0"),
            Selection::set(&[("ad0", 1.0), ("ad2", -0.5)]),
            Selection::Base,
        ] {
            let op = EngineOp {
                selection: &sel,
                handles: &[],
                transition: None,
            };
            let eng: &mut dyn AdapterEngine = &mut f;
            let path = eng.apply(&mut weights, &op).unwrap();
            assert_eq!(path, SwitchPath::Fused);
            assert!(
                weights.bit_equal(&reference_weights(&base, &zoo, &sel)),
                "selection {sel} diverged from the per-policy reference"
            );
        }
        assert!(weights.bit_equal(&base));
        assert_eq!(f.kind(), "fusion");
        assert!(AdapterEngine::counters(&f).applies > 0);
    }

    #[test]
    fn router_routes_mixed_selections_bit_identically() {
        // The acceptance sequence: one router, selections mixing Base,
        // Single and Set, every state bit-identical to the per-policy
        // reference, at 1 and 4 threads.
        let zoo = adapters(3000); // crosses the parallel cutoff at 2 tensors
        let base = base_weights(7);
        let seq = vec![
            Selection::single("ad0"),
            Selection::set(&[("ad0", 1.0), ("ad1", 0.5)]),
            Selection::single_at("ad2", 0.9), // not in roster: via switch engine
            Selection::Base,
            Selection::set(&[("ad1", 2.0), ("ad2", 1.0)]), // roster grows
            Selection::single_at("ad0", 0.5), // roster member: one-member set
            Selection::single("ad0"),         // reweight in place
            Selection::set(&[("ad0", 1.0), ("ad1", 1.0), ("ad2", 1.0)]),
            Selection::Base,
            Selection::single("ad1"),
        ];
        for threads in [1usize, 4] {
            let pool = Arc::new(ThreadPool::new(threads));
            let mut store = store_with(&zoo, Some(Arc::clone(&pool)));
            let mut router = Router::new(base.clone(), Some(pool), false);
            for (step, sel) in seq.iter().enumerate() {
                let applied = router.apply(&mut store, sel).unwrap();
                assert!(applied.switched, "step {step} should switch");
                assert!(
                    router.weights().bit_equal(&reference_weights(&base, &zoo, sel)),
                    "step {step} ({sel}) diverged (threads={threads})"
                );
                assert_eq!(router.active_key(), Some(sel.key().as_str()));
                // Repeating the active selection is free.
                let again = router.apply(&mut store, sel).unwrap();
                assert!(!again.switched, "step {step} repeat should be free");
            }
            router.revert_all(&mut store);
            assert!(router.weights().bit_equal(&base), "threads={threads}");
            assert!(router.fusion().is_none(), "revert_all drops the roster");
        }
    }

    #[test]
    fn router_takes_direct_transitions_when_plans_are_resident() {
        let zoo = adapters(3000);
        let base = base_weights(9);
        let pool = Arc::new(ThreadPool::new(2));
        let mut store = store_with(&zoo, Some(Arc::clone(&pool)));
        // Decode everything, then build the pair plan in the background.
        for a in &zoo {
            store.fetch(&a.name).unwrap();
        }
        let mut router = Router::new(base.clone(), Some(Arc::clone(&pool)), false);
        router.apply(&mut store, &Selection::single("ad0")).unwrap();
        store.prefetch_transitions("ad0", &["ad1".to_string()]);
        pool.join();
        let applied = router.apply(&mut store, &Selection::single("ad1")).unwrap();
        assert_eq!(applied.path, Some(SwitchPath::Transition));
        assert!(router.weights().bit_equal(&reference_weights(
            &base,
            &zoo,
            &Selection::single("ad1")
        )));
        assert!(store.stats().plan_hits >= 1);
        // Cold pair falls back — same bytes.
        let applied = router.apply(&mut store, &Selection::single("ad2")).unwrap();
        assert_eq!(applied.path, Some(SwitchPath::Fallback));
        router.revert_all(&mut store);
        assert!(router.weights().bit_equal(&base));
    }

    #[test]
    fn router_pins_active_and_roster() {
        let zoo = adapters(40);
        let base = base_weights(11);
        let mut store = store_with(&zoo, None);
        let mut router = Router::new(base, None, false);
        router.apply(&mut store, &Selection::single("ad0")).unwrap();
        assert!(store.is_pinned("ad0"));
        router
            .apply(&mut store, &Selection::set(&[("ad1", 1.0), ("ad2", 1.0)]))
            .unwrap();
        assert!(!store.is_pinned("ad0"), "single pin released on set switch");
        assert!(store.is_pinned("ad1") && store.is_pinned("ad2"));
        router.revert_all(&mut store);
        assert!(!store.is_pinned("ad1") && !store.is_pinned("ad2"));
    }

    #[test]
    fn router_roster_grows_lazily_and_survives_non_member_singles() {
        let zoo = adapters(40);
        let base = base_weights(13);
        let mut store = store_with(&zoo, None);
        let mut router = Router::new(base.clone(), None, false);
        router
            .apply(&mut store, &Selection::set(&[("ad0", 1.0)]))
            .unwrap();
        assert_eq!(router.fusion().unwrap().plan().len(), 1);
        // A non-member single empties the set and scatters — the roster
        // is NOT grown by singles.
        router.apply(&mut store, &Selection::single("ad1")).unwrap();
        assert_eq!(router.fusion().unwrap().plan().len(), 1);
        assert!(router.weights().bit_equal(&reference_weights(
            &base,
            &zoo,
            &Selection::single("ad1")
        )));
        // A set naming new members grows the roster (keeping ad0).
        router
            .apply(&mut store, &Selection::set(&[("ad1", 1.0), ("ad2", 0.5)]))
            .unwrap();
        let plan = router.fusion().unwrap().plan();
        assert_eq!(plan.len(), 3);
        for n in ["ad0", "ad1", "ad2"] {
            assert!(plan.member_index(n).is_some(), "{n} in roster");
        }
        router.revert_all(&mut store);
        assert!(router.weights().bit_equal(&base));
    }

    #[test]
    fn wave_panic_during_single_apply_rolls_back_to_base() {
        // Tentpole invariant: a panic out of the apply wave (serial and
        // pooled) surfaces as MutationRolledBack, the resident weights
        // land back on base bit-exactly, every pin is released, and the
        // router keeps serving afterwards.
        let zoo = adapters(3000); // crosses the parallel cutoff when pooled
        let base = base_weights(21);
        for threads in [None, Some(4usize)] {
            let pool = threads.map(|t| Arc::new(ThreadPool::new(t)));
            let mut store = store_with(&zoo, pool.clone());
            let mut router = Router::new(base.clone(), pool, false);
            router.set_fault(FaultPlan::new().panic_wave_at(1).injector());
            let err = router
                .apply(&mut store, &Selection::single("ad0"))
                .unwrap_err();
            match err {
                ServeError::MutationRolledBack { selection, cause } => {
                    assert_eq!(selection, "ad0");
                    assert!(cause.contains("injected fault: wave panic"), "{cause}");
                }
                other => panic!("expected MutationRolledBack, got {other}"),
            }
            assert!(router.weights().bit_equal(&base), "rollback is bit-exact");
            assert_eq!(router.rollbacks(), 1);
            // Truthful key: base IS resident (= Selection::Base.key()).
            assert_eq!(router.active_key(), Some(""));
            assert!(!store.is_pinned("ad0"), "failed apply releases its pin");
            assert_eq!(store.pinned_count(), 0);
            // The injector is spent; the router still serves.
            let sel = Selection::single("ad1");
            let applied = router.apply(&mut store, &sel).unwrap();
            assert!(applied.switched);
            assert!(router
                .weights()
                .bit_equal(&reference_weights(&base, &zoo, &sel)));
        }
    }

    #[test]
    fn wave_panic_during_set_apply_rolls_back_to_base() {
        // From a live single, a set apply panicking in the fused refresh
        // wave must restore base (single support AND the new union),
        // drop the half-built fusion engine, and release roster pins.
        let zoo = adapters(3000);
        let base = base_weights(23);
        let pool = Arc::new(ThreadPool::new(4));
        let mut store = store_with(&zoo, Some(Arc::clone(&pool)));
        let mut router = Router::new(base.clone(), Some(pool), false);
        router.apply(&mut store, &Selection::single("ad0")).unwrap();
        // Wave 1 is the outgoing single's revert; wave 2 the fused refresh.
        router.set_fault(FaultPlan::new().panic_wave_at(2).injector());
        let set = Selection::set(&[("ad1", 1.0), ("ad2", 0.5)]);
        let err = router.apply(&mut store, &set).unwrap_err();
        assert!(matches!(err, ServeError::MutationRolledBack { .. }), "{err}");
        assert!(router.weights().bit_equal(&base));
        assert!(router.fusion().is_none(), "half-built engine dropped");
        assert_eq!(router.rollbacks(), 1);
        for n in ["ad0", "ad1", "ad2"] {
            assert!(!store.is_pinned(n), "{n} unpinned after rollback");
        }
        // Same set succeeds once the injector is spent.
        let applied = router.apply(&mut store, &set).unwrap();
        assert!(applied.switched);
        assert!(router
            .weights()
            .bit_equal(&reference_weights(&base, &zoo, &set)));
    }

    #[test]
    fn wave_panic_during_direct_transition_rolls_back_and_closes_plan() {
        // A panic inside the one-pass A→B transition wave: both
        // adapters' slots restore to base and the in-flight pair plan's
        // pin is closed (no plan refcount leak).
        let zoo = adapters(3000);
        let base = base_weights(25);
        let pool = Arc::new(ThreadPool::new(2));
        let mut store = store_with(&zoo, Some(Arc::clone(&pool)));
        for a in &zoo {
            store.fetch(&a.name).unwrap();
        }
        let mut router = Router::new(base.clone(), Some(Arc::clone(&pool)), false);
        router.apply(&mut store, &Selection::single("ad0")).unwrap();
        store.prefetch_transitions("ad0", &["ad1".to_string()]);
        pool.join();
        router.set_fault(FaultPlan::new().panic_wave_at(1).injector());
        let err = router
            .apply(&mut store, &Selection::single("ad1"))
            .unwrap_err();
        assert!(matches!(err, ServeError::MutationRolledBack { .. }), "{err}");
        assert!(router.weights().bit_equal(&base));
        assert_eq!(store.pinned_plan_count(), 0, "in-flight plan closed");
        assert_eq!(store.pinned_count(), 0);
        assert_eq!(router.rollbacks(), 1);
        let sel = Selection::single("ad1");
        router.apply(&mut store, &sel).unwrap();
        assert!(router
            .weights()
            .bit_equal(&reference_weights(&base, &zoo, &sel)));
    }

    #[test]
    fn wave_panic_while_leaving_fused_state_rolls_back_to_base() {
        // Outgoing-fused coverage: a non-member single whose fused-revert
        // wave panics must restore the union from the fused snapshot —
        // including slots the incoming capture saw at FUSED values.
        let zoo = adapters(3000);
        let base = base_weights(27);
        let pool = Arc::new(ThreadPool::new(4));
        let mut store = store_with(&zoo, Some(Arc::clone(&pool)));
        let mut router = Router::new(base.clone(), Some(pool), false);
        router
            .apply(&mut store, &Selection::set(&[("ad0", 1.0), ("ad1", 0.7)]))
            .unwrap();
        router.set_fault(FaultPlan::new().panic_wave_at(1).injector());
        let err = router
            .apply(&mut store, &Selection::single("ad2"))
            .unwrap_err();
        assert!(matches!(err, ServeError::MutationRolledBack { .. }), "{err}");
        assert!(router.weights().bit_equal(&base));
        assert_eq!(router.rollbacks(), 1);
        let sel = Selection::single("ad2");
        router.apply(&mut store, &sel).unwrap();
        assert!(router
            .weights()
            .bit_equal(&reference_weights(&base, &zoo, &sel)));
    }

    #[test]
    fn lora_outgoing_rollback_lands_in_revert_drift_class() {
        // An active dense-fused LoRA rolled back by a failed SHiRA apply
        // replays the unfuse — float drift in the same class as the
        // engine's own revert (switch.rs drift tests), never bit garbage.
        use crate::adapter::LoraTensor;
        use crate::model::tensor::Tensor2;
        let zoo = adapters(60);
        let base = base_weights(29);
        let mut store = store_with(&zoo, None);
        let mut rng = Rng::new(0x10AD);
        let mk = |rng: &mut Rng, rows: usize, cols: usize| {
            let mut t = Tensor2::zeros(rows, cols);
            rng.fill_normal(&mut t.data, 0.0, 0.1);
            t
        };
        store.add_lora(&LoraAdapter {
            name: "lo".into(),
            scale: 0.5,
            tensors: vec![LoraTensor {
                target: "wq".into(),
                a: mk(&mut rng, DIM, 4),
                b: mk(&mut rng, 4, DIM),
            }],
        });
        let mut router = Router::new(base.clone(), None, false);
        router.apply(&mut store, &Selection::single("lo")).unwrap();
        router.set_fault(FaultPlan::new().panic_wave_at(1).injector());
        let err = router
            .apply(&mut store, &Selection::single("ad0"))
            .unwrap_err();
        assert!(matches!(err, ServeError::MutationRolledBack { .. }), "{err}");
        let drift = router.weights().max_abs_diff(&base);
        assert!(drift < 1e-4, "unfuse-replay drift too large: {drift}");
        assert_eq!(router.rollbacks(), 1);
        router.apply(&mut store, &Selection::single("ad1")).unwrap();
    }

    #[test]
    fn prop_random_mixed_traces_bit_identical_to_reference() {
        // Property form of the acceptance criterion: any selection
        // sequence over a 3-adapter zoo, serial and pooled, lands on the
        // per-policy reference bytes after every apply and reverts to
        // base exactly.
        let pool = Arc::new(ThreadPool::new(4));
        pt::forall(
            0x5E1EC7,
            15,
            |r| {
                let sels: Vec<(u8, usize, usize, f32, f32)> = (0..3 + r.below(6))
                    .map(|_| {
                        (
                            r.below(3) as u8,
                            r.below(3),
                            r.below(3),
                            -1.5 + 3.0 * r.uniform_f32(),
                            -1.5 + 3.0 * r.uniform_f32(),
                        )
                    })
                    .collect();
                (r.next_u64(), sels)
            },
            |&(seed, ref sels)| {
                let mut rng = Rng::new(seed);
                let zoo: Vec<ShiraAdapter> = (0..3)
                    .map(|i| make_adapter(&mut rng, &format!("ad{i}"), 60))
                    .collect();
                let base = base_weights(seed);
                for pooled in [false, true] {
                    let pool = pooled.then(|| Arc::clone(&pool));
                    let mut store = store_with(&zoo, pool.clone());
                    let mut router = Router::new(base.clone(), pool, false);
                    for &(kind, i, j, wa, wb) in sels {
                        let (na, nb) = (format!("ad{i}"), format!("ad{j}"));
                        let sel = match kind {
                            0 => Selection::Base,
                            1 => Selection::single_at(&na, wa),
                            _ => {
                                if i == j {
                                    Selection::set(&[(na.as_str(), wa)])
                                } else {
                                    Selection::set(&[(na.as_str(), wa), (nb.as_str(), wb)])
                                }
                            }
                        };
                        router.apply(&mut store, &sel).unwrap();
                        if !router
                            .weights()
                            .bit_equal(&reference_weights(&base, &zoo, &sel))
                        {
                            return false;
                        }
                    }
                    router.revert_all(&mut store);
                    if !router.weights().bit_equal(&base) {
                        return false;
                    }
                }
                true
            },
        );
    }
}
