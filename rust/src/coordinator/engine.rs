//! The trait-based engine layer behind `Selection` routing: one uniform
//! apply/revert/counters surface ([`AdapterEngine`]) implemented by both
//! the scatter [`SwitchEngine`] and the incremental fused-mode
//! [`FusionEngine`], plus the [`Router`] — the per-request state machine
//! that drives base / single / set selections onto ONE resident weight
//! store (DESIGN.md §12).
//!
//! ## Why a trait
//!
//! Before this redesign the server forked into per-policy code paths at
//! construction time (`Policy::ShiraScatter` vs `Policy::ShiraFusion`)
//! and fused serving was enabled through `enable_fusion` side channels.
//! Both engines now sit behind [`AdapterEngine`]: the server holds one
//! boxed engine for the single-adapter path, dispatches every apply
//! through the same trait call, and the fused-mode engine joins lazily
//! the first time a `Set` selection arrives.  A custom engine (e.g. a
//! mock, or a future GPU-resident path) drops in by implementing the
//! trait and handing [`Router::with_engine`] a box.
//!
//! ## The routing state machine (DESIGN.md §12.2)
//!
//! The router is in one of three live states — `Base`, `Single` (the
//! switch engine holds an applied adapter + snapshot arena) or `Fused`
//! (the fusion engine holds a non-empty fused set).  Transitions:
//!
//! * single→single runs through the PR 4 one-pass
//!   [`transition_to`](SwitchEngine::transition_to) machinery whenever
//!   the store has the pair plan resident, falling back to revert+apply;
//! * set→set (and single↔set where the single is a roster member) runs
//!   through the PR 4 one-wave merged-support
//!   [`apply_set`](FusionEngine::apply_set) — a single adapter is just a
//!   one-member set, the paper's core claim;
//! * crossing between the engines otherwise goes through base: the
//!   outgoing engine's revert is bit-exact for SHiRA, so the incoming
//!   engine always starts from true base values.
//!
//! Every path lands on bytes bit-identical to serving the same
//! selection from base under the old per-policy servers
//! (property-tested below at 1 and 4 threads).

use std::sync::Arc;
use std::time::Instant;

use super::error::ServeError;
use super::fusion_engine::{FusionEngine, FusionPlan};
use super::selection::Selection;
use super::store::{AdapterHandle, AdapterStore, AnyAdapter};
use super::switch::{SwitchEngine, SwitchPath};
use crate::adapter::{AdapterTransition, LoraAdapter};
use crate::model::weights::WeightStore;
use crate::util::threadpool::ThreadPool;

/// One engine operation: the selection to make resident, plus whatever
/// the caller (the router) has already resolved for it — store handles
/// for the named adapters and, for single→single switches, the resident
/// pairwise transition plan.
pub struct EngineOp<'a> {
    /// What should be resident after this call.
    pub selection: &'a Selection,
    /// Decoded store handles for the selection's adapters, positional
    /// with [`Selection::names`].  Engines that resolve adapters
    /// themselves (the fusion engine's roster) may be handed an empty
    /// slice.
    pub handles: &'a [Arc<AdapterHandle>],
    /// Resident A→B transition plan for the (currently-active →
    /// incoming) pair, when the store had one.  `None` falls back to
    /// revert+apply; bytes are identical either way.
    pub transition: Option<Arc<AdapterTransition>>,
}

/// Cumulative counters an engine reports into the serve summary.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineCounters {
    /// Adapter activations / incremental set updates performed.
    pub applies: u64,
    /// One-pass direct A→B transitions among the applies (switch engine).
    pub direct_transitions: u64,
    /// Store-built shard-plan sets ignored as mismatched (switch engine).
    pub plan_mismatches: u64,
}

/// Uniform apply/revert/report surface over the resident weights — the
/// one interface the server's request loop talks to, implemented by
/// [`SwitchEngine`] and [`FusionEngine`].
///
/// Engines never own the weights: the caller owns ONE resident copy of
/// the base model and passes it into every call, so several engines can
/// cooperate on the same store (the router interleaves them).
pub trait AdapterEngine {
    /// Stable name of the engine ("switch" / "fusion") for reports.
    fn kind(&self) -> &'static str;

    /// Make `op.selection` resident on `weights`, transitioning from
    /// whatever this engine currently has applied.  Returns the path the
    /// switch took.
    fn apply(
        &mut self,
        weights: &mut WeightStore,
        op: &EngineOp<'_>,
    ) -> Result<SwitchPath, ServeError>;

    /// Restore base values for everything this engine has applied
    /// (bit-exact for SHiRA state; dense LoRA unfuse leaves float
    /// drift).  A no-op when nothing is applied.
    fn revert(&mut self, weights: &mut WeightStore);

    /// Cumulative counters for the serve summary.
    fn counters(&self) -> EngineCounters;
}

impl AdapterEngine for SwitchEngine {
    fn kind(&self) -> &'static str {
        "switch"
    }

    /// `Base` reverts; `Single` scatters (SHiRA — through the one-pass
    /// transition when `op.transition` is resident) or dense-fuses
    /// (LoRA).  `Set` selections belong to the fusion engine and error.
    fn apply(
        &mut self,
        weights: &mut WeightStore,
        op: &EngineOp<'_>,
    ) -> Result<SwitchPath, ServeError> {
        match op.selection {
            Selection::Base => {
                SwitchEngine::revert(self, weights);
                Ok(SwitchPath::Fallback)
            }
            Selection::Single { name, alpha } => {
                let handle = op
                    .handles
                    .first()
                    .ok_or_else(|| ServeError::UnknownAdapter(name.clone()))?;
                match &handle.adapter {
                    AnyAdapter::Shira(a) => match &op.transition {
                        Some(tp) => {
                            let (_t, path) = self.transition_to(
                                weights,
                                Arc::clone(a),
                                Some(Arc::clone(&handle.plans)),
                                tp,
                                *alpha,
                            );
                            Ok(path)
                        }
                        None => {
                            self.switch_to_shira_planned(
                                weights,
                                Arc::clone(a),
                                Some(Arc::clone(&handle.plans)),
                                *alpha,
                            );
                            Ok(SwitchPath::Fallback)
                        }
                    },
                    AnyAdapter::Lora(a) => {
                        // LoRA strength is baked into the adapter's own
                        // scale; the selection alpha is ignored.
                        self.switch_to_lora_shared(weights, Arc::clone(a));
                        Ok(SwitchPath::Fallback)
                    }
                }
            }
            Selection::Set { .. } => Err(ServeError::InvalidSelection {
                spec: op.selection.key(),
                reason: "set selections route to the fusion engine".into(),
            }),
        }
    }

    fn revert(&mut self, weights: &mut WeightStore) {
        SwitchEngine::revert(self, weights);
    }

    fn counters(&self) -> EngineCounters {
        EngineCounters {
            applies: self.switches,
            direct_transitions: self.transitions,
            plan_mismatches: self.plan_mismatches,
        }
    }
}

impl AdapterEngine for FusionEngine {
    fn kind(&self) -> &'static str {
        "fusion"
    }

    /// Every selection is a fused-set transition: `Base` empties the set,
    /// `Single` is a one-member set (the paper's claim made literal) and
    /// `Set` is the general case — all one merged-support wave via
    /// [`FusionEngine::apply_set`].  Members must be in this engine's
    /// roster; the router guarantees that by (re)building the plan before
    /// dispatching here.
    fn apply(
        &mut self,
        weights: &mut WeightStore,
        op: &EngineOp<'_>,
    ) -> Result<SwitchPath, ServeError> {
        let one;
        let desired: &[(String, f32)] = match op.selection {
            Selection::Base => &[],
            Selection::Single { name, alpha } => {
                one = [(name.clone(), *alpha)];
                &one
            }
            Selection::Set { members } => members,
        };
        self.apply_set(weights, desired)?;
        Ok(SwitchPath::Fused)
    }

    fn revert(&mut self, weights: &mut WeightStore) {
        if self.is_active() {
            // Emptying the set restores base values on the union exactly;
            // the engine stays active so the snapshot is reusable.
            self.apply_set(weights, &[])
                .expect("empty set over an active engine cannot fail");
        }
    }

    fn counters(&self) -> EngineCounters {
        EngineCounters {
            applies: self.updates(),
            direct_transitions: 0,
            plan_mismatches: 0,
        }
    }
}

/// What one [`Router::apply`] did.
#[derive(Clone, Debug, Default)]
pub struct Applied {
    /// Did the resident weights change selection (false when the request
    /// repeats the active selection)?
    pub switched: bool,
    /// The path the apply took, when an engine ran.
    pub path: Option<SwitchPath>,
    /// Microseconds of weight mutation (engine reverts + applies) this
    /// call performed — store fetch/decode and roster (re)builds are
    /// deliberately excluded, so the serving `switch_us` metric keeps
    /// its historical meaning (pure switch cost, not cache misses).
    pub switch_us: f64,
    /// Set when the selection is an unfused-mode LoRA adapter: the
    /// weights stay at base and the caller threads this adapter's
    /// branches through the forward pass instead.
    pub unfused_lora: Option<Arc<LoraAdapter>>,
}

impl Applied {
    fn unchanged() -> Applied {
        Applied::default()
    }
}

/// Which engine currently deviates the weights from base.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Live {
    Base,
    Single,
    Fused,
}

/// The per-request routing state machine: owns the resident weights,
/// the boxed single-adapter engine, and the lazily-built fused-mode
/// engine, and drives any interleaving of base / single / set
/// selections onto them (module docs; DESIGN.md §12.2).
///
/// The router also owns the residency bookkeeping the old server did
/// through side channels: the active single adapter and the whole
/// fusion roster stay pinned in the store, so cache pressure can never
/// evict an adapter an in-flight apply may touch.
pub struct Router {
    weights: WeightStore,
    /// The single-adapter path (normally a [`SwitchEngine`]), behind the
    /// trait so alternative engines can drop in.
    single: Box<dyn AdapterEngine>,
    /// The fused-set path; built on the first `Set` selection and
    /// rebuilt whenever a set names adapters outside the roster.
    fused: Option<FusionEngine>,
    pool: Option<Arc<ThreadPool>>,
    live: Live,
    /// Canonical key of the applied selection.
    active: Option<String>,
    /// Name of the adapter the single engine holds (for pair-plan
    /// lookups and pin bookkeeping).
    single_name: Option<String>,
    pinned_active: Option<String>,
    pinned_roster: Vec<String>,
    /// Serve LoRA singles unfused (branches on the forward pass) instead
    /// of dense-fusing them into the weights.
    lora_unfused: bool,
}

impl Router {
    /// Router over `weights` with a [`SwitchEngine`] single path sharing
    /// `pool` (also used for fused-plan dispatch when sets arrive).
    pub fn new(weights: WeightStore, pool: Option<Arc<ThreadPool>>, lora_unfused: bool) -> Router {
        let engine: Box<dyn AdapterEngine> =
            Box::new(SwitchEngine::with_pool(pool.clone()));
        Self::with_engine(weights, engine, pool, lora_unfused)
    }

    /// Router with a custom boxed single-adapter engine.
    pub fn with_engine(
        weights: WeightStore,
        single: Box<dyn AdapterEngine>,
        pool: Option<Arc<ThreadPool>>,
        lora_unfused: bool,
    ) -> Router {
        Router {
            weights,
            single,
            fused: None,
            pool,
            live: Live::Base,
            active: None,
            single_name: None,
            pinned_active: None,
            pinned_roster: Vec::new(),
            lora_unfused,
        }
    }

    /// The resident weights.
    pub fn weights(&self) -> &WeightStore {
        &self.weights
    }

    /// Canonical key of the currently-applied selection (the batcher's
    /// affinity target).  `None` before the first apply.
    pub fn active_key(&self) -> Option<&str> {
        self.active.as_deref()
    }

    /// The fused-mode engine, once a `Set` selection has built it.
    pub fn fusion(&self) -> Option<&FusionEngine> {
        self.fused.as_ref()
    }

    /// Counters of the single-adapter engine (transitions, mismatches).
    pub fn single_counters(&self) -> EngineCounters {
        self.single.counters()
    }

    /// Counters of the fused-mode engine (incremental updates), zeroed
    /// when no set has arrived yet.
    pub fn fused_counters(&self) -> EngineCounters {
        self.fused
            .as_ref()
            .map(|f| f.counters())
            .unwrap_or_default()
    }

    /// Make `sel` resident, fetching whatever it names from `store` and
    /// picking the cheapest machinery for the transition (module docs).
    /// Repeating the active selection is free (except unfused-LoRA
    /// selections, which re-surface their adapter every call so each
    /// batch can thread the branches through the forward pass).
    pub fn apply(
        &mut self,
        store: &mut AdapterStore,
        sel: &Selection,
    ) -> Result<Applied, ServeError> {
        sel.validate()?;
        let key = sel.key();
        let same = self.active.as_deref() == Some(key.as_str());
        match sel {
            Selection::Base => {
                let switched = self.live != Live::Base;
                let t0 = Instant::now();
                if switched {
                    self.to_base(store);
                }
                self.active = Some(key);
                Ok(Applied {
                    switched,
                    path: None,
                    switch_us: t0.elapsed().as_secs_f64() * 1e6,
                    unfused_lora: None,
                })
            }
            Selection::Single { name, .. } => {
                // Affinity fast path: a repeated selection touches neither
                // the store nor the engines.  (Unfused-LoRA mode must
                // re-surface its adapter every call, so it fetches first.)
                if same && !self.lora_unfused {
                    return Ok(Applied::unchanged());
                }
                let handle = store.fetch(name)?;
                if self.lora_unfused {
                    if let AnyAdapter::Lora(a) = &handle.adapter {
                        // Unfused mode: weights stay at base, branches ride
                        // the forward pass.  Re-surfaced every call.
                        let switched = !same;
                        let t0 = Instant::now();
                        if self.live != Live::Base {
                            self.to_base(store);
                        }
                        self.active = Some(key);
                        return Ok(Applied {
                            switched,
                            path: None,
                            switch_us: t0.elapsed().as_secs_f64() * 1e6,
                            unfused_lora: Some(Arc::clone(a)),
                        });
                    }
                }
                if same {
                    return Ok(Applied::unchanged());
                }
                // A SHiRA single that is already a member of a live fused
                // roster is served AS a one-member set: single↔set moves
                // become one merged-support wave instead of a
                // revert + activate round-trip.
                if matches!(&handle.adapter, AnyAdapter::Shira(_)) {
                    let member = self
                        .fused
                        .as_ref()
                        .map(|f| f.is_active() && f.plan().member_index(name).is_some())
                        .unwrap_or(false);
                    if member {
                        let t0 = Instant::now();
                        if self.live == Live::Single {
                            self.single.revert(&mut self.weights);
                            self.release_single(store);
                            self.live = Live::Base;
                            // Keep `active` truthful at every state change
                            // so an error below cannot leave a stale key.
                            self.active = Some(String::new());
                        }
                        let op = EngineOp {
                            selection: sel,
                            handles: &[],
                            transition: None,
                        };
                        let f = self.fused.as_mut().expect("checked above");
                        let path = f.apply(&mut self.weights, &op)?;
                        self.live = Live::Fused;
                        self.active = Some(key);
                        return Ok(Applied {
                            switched: true,
                            path: Some(path),
                            switch_us: t0.elapsed().as_secs_f64() * 1e6,
                            unfused_lora: None,
                        });
                    }
                }
                // Switch-engine path.  Empty a live fused set first so the
                // engine starts from true base values.
                let t0 = Instant::now();
                if self.live == Live::Fused {
                    if let Some(f) = &mut self.fused {
                        AdapterEngine::revert(f, &mut self.weights);
                    }
                    self.live = Live::Base;
                    self.active = Some(String::new());
                }
                // Pin the incoming adapter before the apply; the previous
                // active adapter's pin is released after.  An in-flight
                // switch can therefore never lose its cache entry.
                store.pin(name);
                if let Some(prev) = self.pinned_active.replace(name.clone()) {
                    if prev != *name {
                        store.unpin(&prev);
                    }
                }
                // Hot pair with a resident pairwise plan: one pass over
                // the A∪B union, one dispatch wave.  Cold pair (or no
                // previous single): revert+apply.  Bytes identical.
                let prev = self
                    .single_name
                    .take()
                    .filter(|p| self.live == Live::Single && p != name);
                let transition = prev
                    .as_deref()
                    .and_then(|p| store.begin_transition(p, name));
                let op = EngineOp {
                    selection: sel,
                    handles: std::slice::from_ref(&handle),
                    transition,
                };
                let took_plan = op.transition.is_some();
                let path = self.single.apply(&mut self.weights, &op)?;
                if took_plan {
                    store.end_transition(prev.as_deref().unwrap_or_default(), name);
                }
                self.live = Live::Single;
                self.single_name = Some(name.clone());
                self.active = Some(key);
                Ok(Applied {
                    switched: true,
                    path: Some(path),
                    switch_us: t0.elapsed().as_secs_f64() * 1e6,
                    unfused_lora: None,
                })
            }
            Selection::Set { members } => {
                if same {
                    return Ok(Applied::unchanged());
                }
                // The fused set is built from base: revert any single
                // first (bit-exact for SHiRA).  `active` tracks every
                // intermediate state so a failed roster build below can
                // never leave a stale key claiming the single is still
                // resident.
                let revert_t0 = Instant::now();
                if self.live == Live::Single {
                    self.single.revert(&mut self.weights);
                    self.release_single(store);
                    self.live = Live::Base;
                    self.active = Some(String::new());
                }
                let revert_us = revert_t0.elapsed().as_secs_f64() * 1e6;
                // Roster (re)builds are lifecycle cost, not switch cost:
                // excluded from the timed window like the store fetch.
                self.ensure_roster(store, members)?;
                let op = EngineOp {
                    selection: sel,
                    handles: &[],
                    transition: None,
                };
                let t0 = Instant::now();
                let f = self.fused.as_mut().expect("ensure_roster built it");
                let path = f.apply(&mut self.weights, &op)?;
                self.live = Live::Fused;
                self.active = Some(key);
                Ok(Applied {
                    switched: true,
                    path: Some(path),
                    switch_us: revert_us + t0.elapsed().as_secs_f64() * 1e6,
                    unfused_lora: None,
                })
            }
        }
    }

    /// Restore base weights exactly and release every pin; drops the
    /// fused-mode engine (the roster shrinks to nothing).  The next set
    /// selection rebuilds it.
    pub fn revert_all(&mut self, store: &mut AdapterStore) {
        self.unpin_roster(store);
        if let Some(mut f) = self.fused.take() {
            f.deactivate(&mut self.weights);
        }
        self.single.revert(&mut self.weights);
        self.release_single(store);
        self.live = Live::Base;
        self.active = None;
    }

    fn to_base(&mut self, store: &mut AdapterStore) {
        match self.live {
            Live::Base => {}
            Live::Single => {
                self.single.revert(&mut self.weights);
                self.release_single(store);
            }
            Live::Fused => {
                if let Some(f) = &mut self.fused {
                    AdapterEngine::revert(f, &mut self.weights);
                }
            }
        }
        self.live = Live::Base;
    }

    fn release_single(&mut self, store: &mut AdapterStore) {
        self.single_name = None;
        if let Some(prev) = self.pinned_active.take() {
            store.unpin(&prev);
        }
    }

    fn unpin_roster(&mut self, store: &mut AdapterStore) {
        for n in self.pinned_roster.drain(..) {
            store.unpin(&n);
        }
    }

    /// Grow (or build) the fusion roster so it covers `members`.
    /// Existing roster members are kept so earlier sets stay addressable
    /// without a rebuild; rosters only shrink via [`Self::revert_all`].
    fn ensure_roster(
        &mut self,
        store: &mut AdapterStore,
        members: &[(String, f32)],
    ) -> Result<(), ServeError> {
        let covered = match &self.fused {
            None => false,
            Some(f) => members
                .iter()
                .all(|(n, _)| f.plan().member_index(n).is_some()),
        };
        if covered {
            return Ok(());
        }
        let mut names: Vec<String> = members.iter().map(|(n, _)| n.clone()).collect();
        if let Some(f) = &self.fused {
            for a in f.plan().roster() {
                if !names.iter().any(|x| x == &a.name) {
                    names.push(a.name.clone());
                }
            }
        }
        names.sort();
        names.dedup();
        // Release the previous roster's pins up front: the fetch loop
        // below pins each new member the moment it lands, and stale pins
        // must neither crowd the new members out of the cache nor leak
        // when the rosters are disjoint.
        self.unpin_roster(store);
        let result = self.build_fusion(store, &names);
        if result.is_err() {
            // Don't leave a half-built roster pinned.
            self.unpin_roster(store);
        }
        result
    }

    fn build_fusion(
        &mut self,
        store: &mut AdapterStore,
        names: &[String],
    ) -> Result<(), ServeError> {
        let mut roster = Vec::with_capacity(names.len());
        for n in names {
            if n.contains('+') || n.contains('@') {
                // '+' and '@' are selection metacharacters: such a name
                // could never be addressed by a set selection.
                return Err(ServeError::InvalidSelection {
                    spec: n.clone(),
                    reason: "roster member name contains a selection metacharacter ('+' or '@')"
                        .into(),
                });
            }
            match &store.fetch(n)?.adapter {
                AnyAdapter::Shira(a) => {
                    roster.push(Arc::clone(a));
                    // Pin as fetched, so a later member's decode can
                    // never evict this one mid-build (pin only fails for
                    // oversized-uncached entries, which were never
                    // resident to protect).
                    if store.pin(n) {
                        self.pinned_roster.push(n.clone());
                    }
                }
                AnyAdapter::Lora(_) => return Err(ServeError::NotShira(n.clone())),
            }
        }
        // Unwind any previous fused state BEFORE snapshotting: a live
        // engine's writes are invisible to `revert`, and dropping it
        // without deactivating would bake its deltas into the new base.
        if let Some(mut f) = self.fused.take() {
            f.deactivate(&mut self.weights);
        }
        self.single.revert(&mut self.weights);
        self.release_single(store);
        self.live = Live::Base;
        // The weights are at base now; record that before the fallible
        // plan build/activate so an error cannot leave a stale key.
        self.active = Some(String::new());
        let plan = FusionPlan::build(roster)?;
        let mut fusion = FusionEngine::with_pool(plan, self.pool.clone());
        fusion.activate(&mut self.weights)?;
        self.fused = Some(fusion);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::sparse::SparseDelta;
    use crate::adapter::ShiraAdapter;
    use crate::coordinator::fusion::fuse_shira;
    use crate::coordinator::store::StoreConfig;
    use crate::util::proptest as pt;
    use crate::util::rng::Rng;

    const DIM: usize = 64;

    fn base_weights(seed: u64) -> WeightStore {
        WeightStore::init(
            &[("wq".into(), vec![DIM, DIM]), ("wk".into(), vec![DIM, DIM])],
            seed,
        )
    }

    fn make_adapter(rng: &mut Rng, name: &str, k: usize) -> ShiraAdapter {
        let mk = |rng: &mut Rng| {
            let idx = rng.sample_indices(DIM * DIM, k);
            let mut d = vec![0.0; k];
            rng.fill_normal(&mut d, 0.0, 0.5);
            SparseDelta::new(DIM, DIM, idx, d)
        };
        ShiraAdapter {
            name: name.into(),
            strategy: "rand".into(),
            tensors: vec![("wq".into(), mk(rng)), ("wk".into(), mk(rng))],
        }
    }

    fn adapters(k: usize) -> Vec<ShiraAdapter> {
        let mut rng = Rng::new(0xE1);
        (0..3)
            .map(|i| make_adapter(&mut rng, &format!("ad{i}"), k))
            .collect()
    }

    fn store_with(adapters: &[ShiraAdapter], pool: Option<Arc<ThreadPool>>) -> AdapterStore {
        let mut store = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: 64 << 20,
                prefetch_depth: 4,
                ..StoreConfig::default()
            },
            pool,
        );
        for a in adapters {
            store.add_shira(a);
        }
        store
    }

    fn scaled(a: &ShiraAdapter, w: f32) -> ShiraAdapter {
        ShiraAdapter {
            name: a.name.clone(),
            strategy: a.strategy.clone(),
            tensors: a
                .tensors
                .iter()
                .map(|(t, d)| (t.clone(), d.scaled(w)))
                .collect(),
        }
    }

    /// The per-policy reference the acceptance criterion names: what the
    /// PR 4 servers would make resident for this selection starting from
    /// base — a scatter apply for singles, a serial `fuse_shira` rebuild
    /// of the scaled members (sorted by name, the roster order) for sets.
    fn reference_weights(
        base: &WeightStore,
        zoo: &[ShiraAdapter],
        sel: &Selection,
    ) -> WeightStore {
        let by_name = |n: &str| zoo.iter().find(|a| a.name == n).expect("known adapter");
        match sel {
            Selection::Base => base.clone(),
            Selection::Single { name, alpha } => {
                let mut w = base.clone();
                for (t, d) in &by_name(name).tensors {
                    d.apply(w.get_mut(t), *alpha);
                }
                w
            }
            Selection::Set { members } => {
                let mut sorted = members.clone();
                sorted.sort_by(|a, b| a.0.cmp(&b.0));
                let scaled_members: Vec<ShiraAdapter> = sorted
                    .iter()
                    .map(|(n, w)| scaled(by_name(n), *w))
                    .collect();
                let refs: Vec<&ShiraAdapter> = scaled_members.iter().collect();
                let fused = fuse_shira(&refs, "reference").expect("same target sets");
                let mut w = base.clone();
                for (t, d) in &fused.tensors {
                    d.apply(w.get_mut(t), 1.0);
                }
                w
            }
        }
    }

    #[test]
    fn boxed_switch_engine_serves_singles_and_rejects_sets() {
        let zoo = adapters(40);
        let base = base_weights(3);
        let mut store = store_with(&zoo, None);
        let mut weights = base.clone();
        let mut eng: Box<dyn AdapterEngine> = Box::new(SwitchEngine::new());
        let sel = Selection::single_at("ad0", 0.8);
        let h = store.fetch("ad0").unwrap();
        let op = EngineOp {
            selection: &sel,
            handles: std::slice::from_ref(&h),
            transition: None,
        };
        let path = eng.apply(&mut weights, &op).unwrap();
        assert_eq!(path, SwitchPath::Fallback);
        assert!(weights.bit_equal(&reference_weights(&base, &zoo, &sel)));
        assert_eq!(eng.kind(), "switch");
        assert_eq!(eng.counters().applies, 1);
        // Sets are the fusion engine's job.
        let set = Selection::set(&[("ad0", 1.0), ("ad1", 1.0)]);
        let op = EngineOp {
            selection: &set,
            handles: &[],
            transition: None,
        };
        assert!(matches!(
            eng.apply(&mut weights, &op),
            Err(ServeError::InvalidSelection { .. })
        ));
        // Base reverts exactly.
        let op = EngineOp {
            selection: &Selection::Base,
            handles: &[],
            transition: None,
        };
        eng.apply(&mut weights, &op).unwrap();
        assert!(weights.bit_equal(&base));
    }

    #[test]
    fn fusion_engine_serves_singles_as_one_member_sets() {
        // The paper's claim made literal: through the trait, a Single on
        // the fusion engine is a one-member set — and bit-identical to
        // the scatter path serving the same single.
        let zoo = adapters(40);
        let base = base_weights(5);
        let roster: Vec<Arc<ShiraAdapter>> =
            zoo.iter().map(|a| Arc::new(a.clone())).collect();
        let plan = FusionPlan::build(roster).unwrap();
        let mut f = FusionEngine::new(plan);
        let mut weights = base.clone();
        f.activate(&mut weights).unwrap();
        for sel in [
            Selection::single_at("ad1", 0.7),
            Selection::single("ad0"),
            Selection::set(&[("ad0", 1.0), ("ad2", -0.5)]),
            Selection::Base,
        ] {
            let op = EngineOp {
                selection: &sel,
                handles: &[],
                transition: None,
            };
            let eng: &mut dyn AdapterEngine = &mut f;
            let path = eng.apply(&mut weights, &op).unwrap();
            assert_eq!(path, SwitchPath::Fused);
            assert!(
                weights.bit_equal(&reference_weights(&base, &zoo, &sel)),
                "selection {sel} diverged from the per-policy reference"
            );
        }
        assert!(weights.bit_equal(&base));
        assert_eq!(f.kind(), "fusion");
        assert!(AdapterEngine::counters(&f).applies > 0);
    }

    #[test]
    fn router_routes_mixed_selections_bit_identically() {
        // The acceptance sequence: one router, selections mixing Base,
        // Single and Set, every state bit-identical to the per-policy
        // reference, at 1 and 4 threads.
        let zoo = adapters(3000); // crosses PAR_MIN_NNZ at 2 tensors
        let base = base_weights(7);
        let seq = vec![
            Selection::single("ad0"),
            Selection::set(&[("ad0", 1.0), ("ad1", 0.5)]),
            Selection::single_at("ad2", 0.9), // not in roster: via switch engine
            Selection::Base,
            Selection::set(&[("ad1", 2.0), ("ad2", 1.0)]), // roster grows
            Selection::single_at("ad0", 0.5), // roster member: one-member set
            Selection::single("ad0"),         // reweight in place
            Selection::set(&[("ad0", 1.0), ("ad1", 1.0), ("ad2", 1.0)]),
            Selection::Base,
            Selection::single("ad1"),
        ];
        for threads in [1usize, 4] {
            let pool = Arc::new(ThreadPool::new(threads));
            let mut store = store_with(&zoo, Some(Arc::clone(&pool)));
            let mut router = Router::new(base.clone(), Some(pool), false);
            for (step, sel) in seq.iter().enumerate() {
                let applied = router.apply(&mut store, sel).unwrap();
                assert!(applied.switched, "step {step} should switch");
                assert!(
                    router.weights().bit_equal(&reference_weights(&base, &zoo, sel)),
                    "step {step} ({sel}) diverged (threads={threads})"
                );
                assert_eq!(router.active_key(), Some(sel.key().as_str()));
                // Repeating the active selection is free.
                let again = router.apply(&mut store, sel).unwrap();
                assert!(!again.switched, "step {step} repeat should be free");
            }
            router.revert_all(&mut store);
            assert!(router.weights().bit_equal(&base), "threads={threads}");
            assert!(router.fusion().is_none(), "revert_all drops the roster");
        }
    }

    #[test]
    fn router_takes_direct_transitions_when_plans_are_resident() {
        let zoo = adapters(3000);
        let base = base_weights(9);
        let pool = Arc::new(ThreadPool::new(2));
        let mut store = store_with(&zoo, Some(Arc::clone(&pool)));
        // Decode everything, then build the pair plan in the background.
        for a in &zoo {
            store.fetch(&a.name).unwrap();
        }
        let mut router = Router::new(base.clone(), Some(Arc::clone(&pool)), false);
        router.apply(&mut store, &Selection::single("ad0")).unwrap();
        store.prefetch_transitions("ad0", &["ad1".to_string()]);
        pool.join();
        let applied = router.apply(&mut store, &Selection::single("ad1")).unwrap();
        assert_eq!(applied.path, Some(SwitchPath::Transition));
        assert!(router.weights().bit_equal(&reference_weights(
            &base,
            &zoo,
            &Selection::single("ad1")
        )));
        assert!(store.stats().plan_hits >= 1);
        // Cold pair falls back — same bytes.
        let applied = router.apply(&mut store, &Selection::single("ad2")).unwrap();
        assert_eq!(applied.path, Some(SwitchPath::Fallback));
        router.revert_all(&mut store);
        assert!(router.weights().bit_equal(&base));
    }

    #[test]
    fn router_pins_active_and_roster() {
        let zoo = adapters(40);
        let base = base_weights(11);
        let mut store = store_with(&zoo, None);
        let mut router = Router::new(base, None, false);
        router.apply(&mut store, &Selection::single("ad0")).unwrap();
        assert!(store.is_pinned("ad0"));
        router
            .apply(&mut store, &Selection::set(&[("ad1", 1.0), ("ad2", 1.0)]))
            .unwrap();
        assert!(!store.is_pinned("ad0"), "single pin released on set switch");
        assert!(store.is_pinned("ad1") && store.is_pinned("ad2"));
        router.revert_all(&mut store);
        assert!(!store.is_pinned("ad1") && !store.is_pinned("ad2"));
    }

    #[test]
    fn router_roster_grows_lazily_and_survives_non_member_singles() {
        let zoo = adapters(40);
        let base = base_weights(13);
        let mut store = store_with(&zoo, None);
        let mut router = Router::new(base.clone(), None, false);
        router
            .apply(&mut store, &Selection::set(&[("ad0", 1.0)]))
            .unwrap();
        assert_eq!(router.fusion().unwrap().plan().len(), 1);
        // A non-member single empties the set and scatters — the roster
        // is NOT grown by singles.
        router.apply(&mut store, &Selection::single("ad1")).unwrap();
        assert_eq!(router.fusion().unwrap().plan().len(), 1);
        assert!(router.weights().bit_equal(&reference_weights(
            &base,
            &zoo,
            &Selection::single("ad1")
        )));
        // A set naming new members grows the roster (keeping ad0).
        router
            .apply(&mut store, &Selection::set(&[("ad1", 1.0), ("ad2", 0.5)]))
            .unwrap();
        let plan = router.fusion().unwrap().plan();
        assert_eq!(plan.len(), 3);
        for n in ["ad0", "ad1", "ad2"] {
            assert!(plan.member_index(n).is_some(), "{n} in roster");
        }
        router.revert_all(&mut store);
        assert!(router.weights().bit_equal(&base));
    }

    #[test]
    fn prop_random_mixed_traces_bit_identical_to_reference() {
        // Property form of the acceptance criterion: any selection
        // sequence over a 3-adapter zoo, serial and pooled, lands on the
        // per-policy reference bytes after every apply and reverts to
        // base exactly.
        let pool = Arc::new(ThreadPool::new(4));
        pt::forall(
            0x5E1EC7,
            15,
            |r| {
                let sels: Vec<(u8, usize, usize, f32, f32)> = (0..3 + r.below(6))
                    .map(|_| {
                        (
                            r.below(3) as u8,
                            r.below(3),
                            r.below(3),
                            -1.5 + 3.0 * r.uniform_f32(),
                            -1.5 + 3.0 * r.uniform_f32(),
                        )
                    })
                    .collect();
                (r.next_u64(), sels)
            },
            |&(seed, ref sels)| {
                let mut rng = Rng::new(seed);
                let zoo: Vec<ShiraAdapter> = (0..3)
                    .map(|i| make_adapter(&mut rng, &format!("ad{i}"), 60))
                    .collect();
                let base = base_weights(seed);
                for pooled in [false, true] {
                    let pool = pooled.then(|| Arc::clone(&pool));
                    let mut store = store_with(&zoo, pool.clone());
                    let mut router = Router::new(base.clone(), pool, false);
                    for &(kind, i, j, wa, wb) in sels {
                        let (na, nb) = (format!("ad{i}"), format!("ad{j}"));
                        let sel = match kind {
                            0 => Selection::Base,
                            1 => Selection::single_at(&na, wa),
                            _ => {
                                if i == j {
                                    Selection::set(&[(na.as_str(), wa)])
                                } else {
                                    Selection::set(&[(na.as_str(), wa), (nb.as_str(), wb)])
                                }
                            }
                        };
                        router.apply(&mut store, &sel).unwrap();
                        if !router
                            .weights()
                            .bit_equal(&reference_weights(&base, &zoo, &sel))
                        {
                            return false;
                        }
                    }
                    router.revert_all(&mut store);
                    if !router.weights().bit_equal(&base) {
                        return false;
                    }
                }
                true
            },
        );
    }
}
