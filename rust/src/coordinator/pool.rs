//! The managed expert pool behind gated serving (DESIGN.md §17): the
//! roster of adapters a [`Gate`](super::gate::Gate) may select, with
//! register/retire lifecycle, a capacity cap, and per-expert utilization
//! counters — shared by `Server` and `Fleet` behind one mutex.
//!
//! The pool deliberately does NOT own adapter bytes; the
//! [`AdapterStore`] keeps doing that.  Registering an expert only makes
//! it *eligible* for gating (its bytes load lazily on first selection,
//! like any adapter), and retiring one removes it from the gate's roster
//! **without downtime**: in-flight and already-resolved selections that
//! name it keep serving, because residency is protected by the store's
//! pin machinery, not by pool membership.  Retire never evicts a pinned
//! roster member — if the expert is pinned by some router's active
//! selection or fusion roster, its bytes stay resident until that pin is
//! released, and the retire simply reports the eviction as deferred.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard};

use super::error::ServeError;
use super::store::AdapterStore;

/// What [`ExpertPool::retire`] did with the expert's resident bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RetireDisposition {
    /// The expert left the roster and nothing protects its bytes: normal
    /// cache pressure may evict them whenever it likes.
    Evictable,
    /// The expert left the roster but its bytes are pinned by a live
    /// selection (active single or fusion roster); eviction is deferred
    /// until the serving side releases the pin.  Never forced.
    DeferredPinned,
}

/// One pooled expert's lifecycle state.
#[derive(Clone, Debug, Default)]
struct Expert {
    /// Retired experts stay in the map (their utilization history is
    /// part of the report) but leave the gate's roster.
    active: bool,
    /// Requests whose resolved selection included this expert.
    served: u64,
}

/// The expert roster a gate selects over.  See the module docs for the
/// lifecycle contract; construction is via [`ExpertPool::new`] /
/// [`ExpertPool::shared`].
#[derive(Debug, Default)]
pub struct ExpertPool {
    capacity: usize,
    experts: BTreeMap<String, Expert>,
}

/// The pool handle `Server` and `Fleet` share: one mutex, many fronts.
pub type SharedExpertPool = Arc<Mutex<ExpertPool>>;

/// Lock a shared pool, absorbing poison (a panicked holder cannot have
/// left the map structurally broken: every mutation is a single insert
/// or field store).
pub fn lock_pool(pool: &SharedExpertPool) -> MutexGuard<'_, ExpertPool> {
    pool.lock().unwrap_or_else(|p| p.into_inner())
}

impl ExpertPool {
    /// Pool with an active-expert capacity cap; `0` means unbounded.
    pub fn new(capacity: usize) -> ExpertPool {
        ExpertPool {
            capacity,
            experts: BTreeMap::new(),
        }
    }

    /// A shareable pool (the form the builders take).
    pub fn shared(capacity: usize) -> SharedExpertPool {
        Arc::new(Mutex::new(ExpertPool::new(capacity)))
    }

    /// The configured capacity cap (`0` = unbounded).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Experts ever registered (active + retired).
    pub fn len(&self) -> usize {
        self.experts.len()
    }

    /// True when no expert was ever registered.
    pub fn is_empty(&self) -> bool {
        self.experts.is_empty()
    }

    /// Currently-active experts.
    pub fn active_len(&self) -> usize {
        self.experts.values().filter(|e| e.active).count()
    }

    /// Is `name` registered and active (i.e. gate-selectable)?
    pub fn is_active(&self, name: &str) -> bool {
        self.experts.get(name).is_some_and(|e| e.active)
    }

    /// Register (or re-activate) an expert.  Fails when the active
    /// roster is at capacity; re-registering an active expert is a
    /// no-op, and re-activating a retired one keeps its utilization
    /// history.  No bytes move here — residency is lazy, via the store.
    pub fn register(&mut self, name: &str) -> Result<(), ServeError> {
        if self.experts.get(name).is_some_and(|e| e.active) {
            return Ok(());
        }
        if self.capacity > 0 && self.active_len() >= self.capacity {
            return Err(ServeError::Gate {
                reason: format!(
                    "expert pool at capacity ({}): cannot register {name:?} \
                     (retire an expert first)",
                    self.capacity
                ),
            });
        }
        self.experts.entry(name.to_string()).or_default().active = true;
        Ok(())
    }

    /// Retire an expert: it leaves the gate's roster immediately (the
    /// next resolved request cannot select it) but its bytes are never
    /// force-evicted — see [`RetireDisposition`].  Unknown names error.
    pub fn retire(
        &mut self,
        name: &str,
        store: &AdapterStore,
    ) -> Result<RetireDisposition, ServeError> {
        match self.experts.get_mut(name) {
            Some(e) => {
                e.active = false;
                Ok(if store.is_pinned(name) {
                    RetireDisposition::DeferredPinned
                } else {
                    RetireDisposition::Evictable
                })
            }
            None => Err(ServeError::Gate {
                reason: format!("cannot retire unknown expert {name:?}"),
            }),
        }
    }

    /// The gate's roster: active expert names, sorted (BTreeMap order),
    /// so every consumer sees one canonical ordering.
    pub fn roster(&self) -> Vec<String> {
        self.experts
            .iter()
            .filter(|(_, e)| e.active)
            .map(|(n, _)| n.clone())
            .collect()
    }

    /// Count one resolved request against each expert it selected.
    /// Unknown names are ignored (a hand-built explicit set may name
    /// adapters outside the pool).
    pub fn record_served(&mut self, names: &[&str]) {
        for n in names {
            if let Some(e) = self.experts.get_mut(*n) {
                e.served += 1;
            }
        }
    }

    /// Per-expert utilization, sorted by name; retired experts keep
    /// their history (the serve reports surface this).
    pub fn utilization(&self) -> Vec<(String, u64)> {
        self.experts
            .iter()
            .map(|(n, e)| (n.clone(), e.served))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::store::StoreConfig;
    use crate::data::synth::{adapter_names, toy_shira_zoo};

    fn store_with_zoo(names: &[String]) -> AdapterStore {
        let mut store = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: 64 << 20,
                ..StoreConfig::default()
            },
            None,
        );
        for a in &toy_shira_zoo(16, names, 20, 7) {
            store.add_shira(a);
        }
        store
    }

    #[test]
    fn register_retire_lifecycle_and_capacity() {
        let names = adapter_names(3);
        let store = store_with_zoo(&names);
        let mut pool = ExpertPool::new(2);
        pool.register("adapter0").unwrap();
        pool.register("adapter1").unwrap();
        assert_eq!(pool.active_len(), 2);
        // At capacity: the third registration is a structured error.
        let err = pool.register("adapter2").unwrap_err();
        assert_eq!(err.kind(), "gate");
        assert!(err.to_string().contains("capacity"));
        // Re-registering an active expert is a free no-op.
        pool.register("adapter0").unwrap();
        assert_eq!(pool.active_len(), 2);
        // Retiring frees a slot; history survives re-activation.
        pool.record_served(&["adapter0", "adapter1"]);
        assert_eq!(
            pool.retire("adapter0", &store).unwrap(),
            RetireDisposition::Evictable
        );
        assert!(!pool.is_active("adapter0"));
        assert_eq!(pool.roster(), vec!["adapter1".to_string()]);
        pool.register("adapter2").unwrap();
        pool.register("adapter0").unwrap();
        assert_eq!(pool.active_len(), 2);
        assert!(pool.retire("ghost", &store).is_err());
        let util = pool.utilization();
        assert_eq!(util.len(), 3);
        assert!(util.contains(&("adapter0".to_string(), 1)));
        assert_eq!(pool.capacity(), 2);
        assert_eq!(pool.len(), 3);
        assert!(!pool.is_empty());
    }

    #[test]
    fn retire_defers_eviction_for_pinned_experts() {
        // The acceptance invariant at unit scope: retiring an expert
        // whose bytes a live selection has pinned reports the eviction
        // as deferred and leaves the pin (and the bytes) untouched.
        let names = adapter_names(2);
        let mut store = store_with_zoo(&names);
        store.fetch("adapter0").unwrap();
        store.pin("adapter0");
        let mut pool = ExpertPool::new(0);
        pool.register("adapter0").unwrap();
        pool.register("adapter1").unwrap();
        assert_eq!(
            pool.retire("adapter0", &store).unwrap(),
            RetireDisposition::DeferredPinned
        );
        assert!(store.is_pinned("adapter0"), "retire must not unpin");
        assert!(store.is_resident("adapter0"), "retire must not evict");
        assert_eq!(pool.roster(), vec!["adapter1".to_string()]);
    }

    #[test]
    fn unbounded_pool_and_shared_handle() {
        let pool = ExpertPool::shared(0);
        for n in adapter_names(10) {
            lock_pool(&pool).register(&n).unwrap();
        }
        assert_eq!(lock_pool(&pool).active_len(), 10);
        assert_eq!(lock_pool(&pool).roster().len(), 10);
        lock_pool(&pool).record_served(&["adapter3", "not-in-pool"]);
        let util = lock_pool(&pool).utilization();
        assert!(util.contains(&("adapter3".to_string(), 1)));
        assert!(!util.iter().any(|(n, _)| n == "not-in-pool"));
    }
}
