//! `shira` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   info          print manifest/artifact summary
//!   train         finetune one adapter and save it
//!   eval          evaluate an adapter file on the task suite
//!   serve         run a serving trace (mixed selections, or a gated fleet)
//!   fuse          fuse several SHiRA adapter files
//!   switch-bench  quick Fig.5-style scatter-vs-fuse sweep
//!   repro         regenerate a paper table/figure (or `--exp all`)

use std::sync::Arc;

use anyhow::{anyhow, Result};

use shira::adapter::io;
use shira::adapter::kernel;
use shira::adapter::mask::MaskStrategy;
use shira::config::RunConfig;
use shira::coordinator::switch::SwitchEngine;
use shira::coordinator::fleet::Fleet;
use shira::coordinator::pool::{lock_pool, ExpertPool};
use shira::coordinator::selection::Selection;
use shira::coordinator::server::{FailurePolicy, Server};
use shira::coordinator::store::StoreConfig;
use shira::train::gate::train_gate;
use shira::util::threadpool::ThreadPool;
use shira::data::synth::{
    adapter_names, fleet_trace, synth_shira_adapter, toy_base, toy_shira_zoo,
    FLEET_TRACE_USERS,
};
use shira::data::tasks::{Task, ALL_TASKS};
use shira::data::trace::{generate_trace, mixed_selections, switch_count, TracePattern};
use shira::model::weights::WeightStore;
use shira::repro;
use shira::runtime::Runtime;
use shira::train::eval::eval_tasks;
use shira::train::schedule::Schedule;
use shira::train::{Trainer, TrainKind};
use shira::util::cli::Args;
use shira::util::rng::Rng;
use shira::runtime::HostValue;

const SUBCOMMANDS: &[&str] = &[
    "info", "train", "eval", "serve", "fuse", "switch-bench", "repro",
];

const USAGE: &str = "\
shira — Sparse High Rank Adapters: rapid-switching adapter framework

USAGE: shira <subcommand> [flags]

  info                             manifest + artifact summary
  train --kind <lora|dora|shira-{struct,rand,wm,grad,snip}|shira-wm-dora>
        [--task <name>|mixture] [--steps N] [--out adapter.bin]
  eval  --adapter <file> [--tasks all|t1,t2] [--eval-examples N]
  serve [--pattern bursty|uniform|rr|zipf] [--trace-len N] [--adapters N]
        [--cache-bytes N] [--prefetch-depth N] [--format v1|v2|v2-f16]
        [--plan-cache-bytes N]   (0 disables direct A->B transitions)
        [--kernel scalar|simd]   (force the scatter kernel dispatch)
        [--f16-resident]         (keep v2-f16 deltas binary16 in cache)
        [--affinity]             (striped shard->worker affinity hints)
        [--replicas N] [--queue-depth N] [--burst N] [--concurrent]
        (--replicas selects the artifact-free N-replica fleet over the
        seeded 10k-user zipf trace; otherwise one server, one replica)
        [--deadline-ms N]     (end-to-end request deadline, 0 disables)
        [--retry-budget N]    (re-dispatch attempts per request)
        [--replica-quarantine-ttl-ms N]  (base replica-quarantine TTL;
        doubles per re-quarantine, probation + recovery on expiry)
        [--gate]              (fleet path: train a top-k gate and serve an
        @auto trace — each request's expert set is gate-selected)
        [--top-k N]           (experts kept per gated selection; default 2)
        [--pool-cap N]        (expert-pool capacity; 0 = unbounded)
  fuse  --out <file> <a.shira> <b.shira> ...
  switch-bench [--dims 512,1024,2048,4096] [--frac 0.02] [--rank 32]
  repro --exp <table1..6|fig4|fig5|fig6|fig7|gate|orthogonality|all> [--fast]

Common flags: --seed N --steps N --fast --config cfg.json --report-dir DIR
";

fn main() {
    shira::util::log::init();
    let args = match Args::from_env(SUBCOMMANDS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let sub = args.subcommand.clone().unwrap_or_else(|| "info".to_string());
    if args.has("help") {
        println!("{USAGE}");
        return;
    }
    let result = dispatch(&sub, &args);
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(sub: &str, args: &Args) -> Result<()> {
    match sub {
        "info" => cmd_info(),
        "train" => cmd_train(args),
        "eval" => cmd_eval(args),
        "serve" => cmd_serve(args),
        "fuse" => cmd_fuse(args),
        "switch-bench" => cmd_switch_bench(args),
        "repro" => cmd_repro(args),
        other => Err(anyhow!("unknown subcommand {other}\n{USAGE}")),
    }
}

fn parse_kind(s: &str) -> Result<TrainKind> {
    Ok(match s {
        "lora" => TrainKind::Lora,
        "dora" => TrainKind::Dora,
        "full" => TrainKind::Full,
        "shira-wm-dora" => TrainKind::ShiraDora(MaskStrategy::WeightMagnitude),
        _ => {
            if let Some(m) = s.strip_prefix("shira-dense-") {
                TrainKind::ShiraDense(
                    MaskStrategy::parse(m).ok_or_else(|| anyhow!("bad mask {m}"))?,
                )
            } else if let Some(m) = s.strip_prefix("shira-") {
                TrainKind::Shira(
                    MaskStrategy::parse(m).ok_or_else(|| anyhow!("bad mask {m}"))?,
                )
            } else {
                return Err(anyhow!("unknown adapter kind '{s}'"));
            }
        }
    })
}

fn cmd_info() -> Result<()> {
    let rt = Runtime::with_default_artifacts()?;
    println!("platform: {}", rt.platform());
    println!("artifacts dir: {}", rt.manifest.dir.display());
    let mut names: Vec<&String> = rt.manifest.artifacts.keys().collect();
    names.sort();
    println!("artifacts ({}):", names.len());
    for n in names {
        let a = &rt.manifest.artifacts[n];
        println!(
            "  {n:28} inputs={:2} outputs={}",
            a.inputs.len(),
            a.outputs.len()
        );
    }
    for (name, m) in [
        ("llama", rt.manifest.model("llama")),
        ("sd", rt.manifest.model("sd")),
    ] {
        let m = m.map_err(|e| anyhow!("{e}"))?;
        println!(
            "model {name}: {} params across {} tensors, {} targets",
            m.total_params(),
            m.params.len(),
            m.targets.len()
        );
        for (k, v) in [
            ("shira", m.theta_len.get("shira")),
            ("lora", m.theta_len.get("lora")),
            ("dora", m.theta_len.get("dora")),
        ] {
            if let Some(v) = v {
                println!(
                    "  theta[{k}] = {v} ({:.2}% of model)",
                    100.0 * *v as f64 / m.total_params() as f64
                );
            }
        }
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args).map_err(|e| anyhow!(e))?;
    let rt = Runtime::with_default_artifacts()?;
    let kind = parse_kind(args.get_or("kind", "shira-wm"))?;
    let base = repro::ensure_llama_base(&rt, &cfg, "llama_a")?;
    let trainer = Trainer::new(&rt, "llama", base)?;
    let (b, t) = (trainer.model.dim("batch"), trainer.model.dim("seq_len"));
    let task_flag = args.get_or("task", "mixture").to_string();
    let tasks: Vec<Task> = if task_flag == "mixture" {
        ALL_TASKS.to_vec()
    } else {
        vec![Task::parse(&task_flag).ok_or_else(|| anyhow!("unknown task {task_flag}"))?]
    };
    let lr = match kind {
        TrainKind::Lora | TrainKind::Dora => cfg.lr_lora as f32,
        _ => cfg.lr_shira as f32,
    };
    let seed = cfg.seed;
    let mut data = move |_s: usize, rng: &mut Rng| {
        let batch = shira::data::tasks::mixture_batch(&tasks, b, t, seed, rng);
        vec![
            HostValue::i32(batch.x, vec![b, t]),
            HostValue::i32(batch.y, vec![b, t]),
            HostValue::f32(batch.mask, vec![b, t]),
        ]
    };
    let out = trainer.train(
        kind,
        cfg.adapter_steps,
        Schedule::Linear { lr, floor_frac: 0.1 },
        &mut data,
        cfg.seed,
    )?;
    println!(
        "{}: loss {:.4} -> {:.4}, {:.2} steps/s, {} trainable params, peak mem {}",
        out.kind_label,
        out.first_loss(),
        out.last_loss(),
        out.steps_per_sec,
        out.trainable_params,
        shira::util::alloc::fmt_bytes(out.peak_bytes)
    );
    if let Some(path) = args.get("out") {
        match kind {
            TrainKind::Shira(s) => {
                let a = trainer.export_shira(&out, &task_flag, s);
                io::save_shira(std::path::Path::new(path), &a)
                    .map_err(|e| anyhow!("{e}"))?;
                println!("saved SHiRA adapter ({} bytes payload) -> {path}", a.nbytes());
            }
            TrainKind::Lora => {
                let a = trainer.export_lora(&out, &task_flag);
                io::save_lora(std::path::Path::new(path), &a)
                    .map_err(|e| anyhow!("{e}"))?;
                println!("saved LoRA adapter -> {path}");
            }
            _ => println!("(--out supports shira-* and lora kinds)"),
        }
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args).map_err(|e| anyhow!(e))?;
    let rt = Runtime::with_default_artifacts()?;
    let base = repro::ensure_llama_base(&rt, &cfg, "llama_a")?;
    let mut weights = base.clone();
    if let Some(path) = args.get("adapter") {
        let path = std::path::Path::new(path);
        let mut engine = SwitchEngine::new();
        if let Ok(a) = io::load_shira(path) {
            println!("applying SHiRA adapter '{}' ({} nnz)", a.name, a.param_count());
            engine.switch_to_shira(&mut weights, &a, args.get_f64("alpha", 1.0)? as f32);
        } else {
            let a = io::load_lora(path).map_err(|e| anyhow!("{e}"))?;
            println!("fusing LoRA adapter '{}'", a.name);
            engine.switch_to_lora(&mut weights, &a);
        }
    }
    let task_flag = args.get_or("tasks", "all");
    let tasks: Vec<Task> = if task_flag == "all" {
        ALL_TASKS.to_vec()
    } else {
        task_flag
            .split(',')
            .map(|t| Task::parse(t).ok_or_else(|| anyhow!("unknown task {t}")))
            .collect::<Result<_>>()?
    };
    let (per, avg) = eval_tasks(&rt, &weights, &tasks, cfg.eval_examples, cfg.seed)?;
    for (task, acc) in per {
        println!("{:12} {:5.1}%", task.name(), acc);
    }
    println!("{:12} {:5.1}%", "average", avg);
    Ok(())
}

/// Apply the `--kernel scalar|simd` override: forces the process-wide
/// scatter-kernel dispatch before any pool or engine probes it
/// (DESIGN.md §15.2).  Bytes are identical under either mode.
fn apply_kernel_flag(args: &Args) -> Result<()> {
    if let Some(k) = args.get("kernel") {
        let d = kernel::KernelDispatch::parse(k)
            .ok_or_else(|| anyhow!("bad --kernel {k} (expected scalar|simd)"))?;
        kernel::force_dispatch(d);
    }
    Ok(())
}

/// `serve --replicas N`: the artifact-free fleet path (DESIGN.md §14).
/// Toy base weights and the seeded synth zoo — the same construction
/// the fleet tests and the bench gate replay — so it runs anywhere.
fn cmd_serve_fleet(args: &Args, cfg: &RunConfig) -> Result<()> {
    const DIM: usize = 64;
    const NNZ: usize = 400;
    let replicas = args.get_usize("replicas", 2)?;
    let queue_depth = args.get_usize("queue-depth", 16)?;
    let n_adapters = args.get_usize("adapters", 4)?;
    let burst = args.get_usize("burst", 8)?;
    let deadline_ms = args.get_u64("deadline-ms", 0)?;
    let retry_budget = args.get_usize("retry-budget", 3)?;
    let quarantine_ttl_ms = args.get_u64("replica-quarantine-ttl-ms", 250)?;
    let default_cfg = StoreConfig::default();
    let names = adapter_names(n_adapters);
    let pool = Arc::new(ThreadPool::host_sized());
    if args.has("affinity") {
        pool.set_affinity_hints(true);
    }
    let use_gate = args.has("gate");
    let mut builder = Fleet::builder(toy_base(DIM, cfg.seed))
        .replicas(replicas)
        .queue_depth(queue_depth)
        .shira_adapters(&toy_shira_zoo(DIM, &names, NNZ, cfg.seed))
        .store_config(StoreConfig {
            cache_bytes: cfg.cache_bytes,
            prefetch_depth: args.get_usize("prefetch-depth", default_cfg.prefetch_depth)?,
            plan_cache_bytes: args
                .get_usize("plan-cache-bytes", default_cfg.plan_cache_bytes)?,
            f16_resident: args.has("f16-resident"),
            ..default_cfg
        })
        .pool(pool)
        .failure_policy(FailurePolicy::DegradeToBase)
        .deadline_us(deadline_ms.saturating_mul(1_000))
        .retry_budget(retry_budget as u32)
        .replica_quarantine_ttl_us(quarantine_ttl_ms.saturating_mul(1_000).max(1));
    if use_gate {
        let top_k = args.get_usize("top-k", 2)?;
        let pool_cap = args.get_usize("pool-cap", 0)?;
        let trained = train_gate(&names, top_k, 2000, cfg.seed);
        println!(
            "gate: linear top-{top_k} over {} experts, held-out accuracy {:.1}%, \
             pool cap {}",
            names.len(),
            100.0 * trained.accuracy,
            if pool_cap == 0 {
                "unbounded".to_string()
            } else {
                pool_cap.to_string()
            },
        );
        let expert_pool = ExpertPool::shared(pool_cap);
        for n in &names {
            lock_pool(&expert_pool).register(n).map_err(|e| anyhow!("{e}"))?;
        }
        builder = builder
            .gate(Arc::new(trained.gate))
            .expert_pool(expert_pool);
    }
    let mut fleet = builder.build();
    let sels = if use_gate {
        vec![Selection::Auto]
    } else {
        mixed_selections(&names)
    };
    let trace = fleet_trace(&sels, cfg.trace_len, burst, cfg.seed);
    println!(
        "fleet: {replicas} replicas, queue depth {queue_depth}, {} adapters, \
         {} requests (zipf {FLEET_TRACE_USERS} users, burst {burst}, seed {}) \
         mode={}{} kernel={}",
        n_adapters,
        trace.len(),
        cfg.seed,
        if args.has("concurrent") {
            "concurrent"
        } else {
            "deterministic"
        },
        if use_gate { "+gated" } else { "" },
        kernel::active_dispatch().name(),
    );
    let report = if args.has("concurrent") {
        fleet.run_trace_concurrent(&trace)?
    } else {
        fleet.run_trace(&trace, cfg.seed)?
    };
    println!("{}", report.summary);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args).map_err(|e| anyhow!(e))?;
    // Force the kernel dispatch FIRST, before any pool/engine probes it.
    apply_kernel_flag(args)?;
    // The --policy alias is gone (it deprecated when requests grew
    // per-request selections): fail with the migration path instead of
    // silently ignoring the flag.
    if let Some(p) = args.get("policy") {
        return Err(anyhow!(
            "--policy {p} was removed: requests carry per-request selections \
             now. Omit --policy for the default mixed base/single/set trace, \
             or use `serve --replicas N --gate` for learned top-k gated \
             selection over the expert pool"
        ));
    }
    // The fleet path is runtime-free: no artifacts needed.
    if args.has("replicas") {
        return cmd_serve_fleet(args, &cfg);
    }
    let rt = Runtime::with_default_artifacts()?;
    let pattern = match args.get_or("pattern", "bursty") {
        "bursty" => TracePattern::Bursty { burst: 8 },
        "uniform" => TracePattern::UniformMix,
        "rr" => TracePattern::RoundRobin,
        "zipf" => TracePattern::ZipfUsers {
            users: FLEET_TRACE_USERS,
            burst: args.get_usize("burst", 8)?,
        },
        p => return Err(anyhow!("unknown pattern {p}")),
    };
    let n_adapters = args.get_usize("adapters", 4)?;
    let meta = rt.manifest.model("llama").map_err(|e| anyhow!("{e}"))?;
    let base = WeightStore::init(&meta.params, cfg.seed);
    let default_cfg = StoreConfig::default();
    let store_cfg = StoreConfig {
        cache_bytes: cfg.cache_bytes,
        prefetch_depth: args.get_usize("prefetch-depth", default_cfg.prefetch_depth)?,
        format: {
            let f = args.get_or("format", default_cfg.format.name());
            shira::adapter::io::Format::parse(f)
                .ok_or_else(|| anyhow!("bad --format {f} (expected v1|v2|v2-f16)"))?
        },
        plan_cache_bytes: args
            .get_usize("plan-cache-bytes", default_cfg.plan_cache_bytes)?,
        f16_resident: args.has("f16-resident"),
        ..default_cfg
    };
    let plan_cache_bytes = store_cfg.plan_cache_bytes;
    let pool = Arc::new(ThreadPool::host_sized());
    if args.has("affinity") {
        pool.set_affinity_hints(true);
    }
    let mut server = Server::builder(&rt, base)
        .model("llama")
        .store_config(store_cfg)
        .pool(pool)
        .build()?;

    // Seeded SHiRA synth zoo shared with the serving bench and the
    // fleet tests (data::synth); the mixed default trace exercises
    // scatter and fused sets per-request.
    let names = adapter_names(n_adapters);
    for name in &names {
        server
            .store
            .add_shira(&synth_shira_adapter(meta, name, cfg.seed));
    }
    // One trace mixing base, every single, and rotating sets —
    // exercising all three routing arms per-request.
    let selections: Vec<Selection> = mixed_selections(&names);
    let flash_bytes: usize = names
        .iter()
        .filter_map(|n| server.store.encoded_len(n))
        .sum();
    println!(
        "flash: {} adapters, {} encoded ({} format), cache budget {}, \
         prefetch depth {}, plan cache {}",
        names.len(),
        shira::util::alloc::fmt_bytes(flash_bytes),
        server.store.format().name(),
        shira::util::alloc::fmt_bytes(cfg.cache_bytes),
        server.store.prefetch_depth(),
        shira::util::alloc::fmt_bytes(plan_cache_bytes),
    );
    let trace = generate_trace(&selections, cfg.trace_len, pattern, 1e4, cfg.seed);
    println!(
        "serving {} requests over {} selections (pattern switches: {}) \
         mode=mixed-selections kernel={}",
        trace.len(),
        selections.len(),
        switch_count(&trace),
        kernel::active_dispatch().name(),
    );
    let report = server.run_trace(&trace)?;
    println!("{}", report.summary);
    Ok(())
}

fn cmd_fuse(args: &Args) -> Result<()> {
    let out_path = args
        .get("out")
        .ok_or_else(|| anyhow!("--out required"))?
        .to_string();
    if args.positional.is_empty() {
        return Err(anyhow!("give at least one .shira file"));
    }
    let adapters: Vec<shira::adapter::ShiraAdapter> = args
        .positional
        .iter()
        .map(|p| io::load_shira(std::path::Path::new(p)).map_err(|e| anyhow!("{p}: {e}")))
        .collect::<Result<_>>()?;
    let refs: Vec<&shira::adapter::ShiraAdapter> = adapters.iter().collect();
    let fused = shira::coordinator::fusion::fuse_shira(&refs, "fused")?;
    let report = shira::coordinator::fusion::analyze_shira(&refs);
    println!(
        "fused {} adapters: nnz={} overlap={:.4} ataDensity={:.4} collisions={}",
        adapters.len(),
        fused.param_count(),
        report.mean_overlap,
        report.mean_ata_density,
        report.collisions
    );
    io::save_shira(std::path::Path::new(&out_path), &fused).map_err(|e| anyhow!("{e}"))?;
    println!("-> {out_path}");
    Ok(())
}

fn cmd_switch_bench(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args).map_err(|e| anyhow!(e))?;
    let dims: Vec<usize> = args
        .get_or("dims", "512,1024,2048,4096")
        .split(',')
        .map(|d| d.parse().map_err(|_| anyhow!("bad dim {d}")))
        .collect::<Result<_>>()?;
    let frac = args.get_f64("frac", 0.02)?;
    let rank = args.get_usize("rank", 32)?;
    println!("| dim | scatter (us) | fuse (us) | speedup |");
    println!("|---|---|---|---|");
    for dim in dims {
        let s = shira::repro::systems::measure_switch(dim, frac, rank, 10, cfg.seed);
        println!(
            "| {} | {:.1} | {:.1} | {:.1}x |",
            s.dim, s.scatter_us, s.fuse_us, s.speedup
        );
    }
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args).map_err(|e| anyhow!(e))?;
    let exp = args.get_or("exp", "all").to_string();
    let rt = Runtime::with_default_artifacts()?;
    let reports = repro::run(&rt, &cfg, &exp)?;
    println!(
        "\nwrote {} report(s) to {}/",
        reports.len(),
        cfg.report_dir
    );
    Ok(())
}
