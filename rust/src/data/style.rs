//! Synthetic style-transfer proxy for the vision experiments (DESIGN.md §3).
//!
//! `nanosd` maps a content latent z to an "image" vector.  A *style* is an
//! affine transform in image space (gain, shift, and a style direction) —
//! the analogue of Bluefire's "blue fire effect" / Paintings' texture.
//! Concepts are clusters in z-space; each style's training set covers some
//! concepts and holds others out (the paper's unseen koala/lion prompts).
//!
//! Quality metric: SPS (Style-Preference Score), an HPSv2 proxy —
//! geometric mean of style-match and content-preservation, scaled to the
//! paper's ~0-40 range.  It is monotone in both failure modes HPSv2
//! penalizes: missing style and lost/garbled concept.

use crate::util::rng::Rng;

/// Number of distinct content concepts (paper: 9 paintings / 6 bluefire).
pub const N_CONCEPTS: usize = 9;

/// The two trained styles (paper §4.2's bluefire and paintings LoRAs).
///
/// # Examples
///
/// ```
/// use shira::data::style::Style;
/// assert_eq!(Style::parse("bluefire"), Some(Style::Bluefire));
/// assert_eq!(Style::Paintings.name(), "paintings");
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Style {
    /// The "blue fire effect" style.
    Bluefire,
    /// The "paintings" texture style.
    Paintings,
}

/// Both styles, in report order.
pub const ALL_STYLES: [Style; 2] = [Style::Bluefire, Style::Paintings];

impl Style {
    /// Stable CLI / report name of the style.
    pub fn name(&self) -> &'static str {
        match self {
            Style::Bluefire => "bluefire",
            Style::Paintings => "paintings",
        }
    }

    /// Parse a style by its [`Self::name`].
    pub fn parse(s: &str) -> Option<Style> {
        ALL_STYLES.iter().copied().find(|x| x.name() == s)
    }

    /// Concepts included in this style's TRAINING set (others are the
    /// held-out "unseen concept" prompts, e.g. the koala).
    pub fn train_concepts(&self) -> std::ops::Range<usize> {
        match self {
            Style::Bluefire => 0..6,
            Style::Paintings => 3..9,
        }
    }
}

/// The synthetic vision world: fixed concept anchors, the ground-truth
/// content renderer, and the two style transforms.
#[derive(Clone, Debug)]
pub struct StyleWorld {
    /// Content-latent dimensionality.
    pub d_z: usize,
    /// Image-vector dimensionality.
    pub d_img: usize,
    /// concept anchors in z-space, (N_CONCEPTS, d_z)
    anchors: Vec<Vec<f32>>,
    /// ground-truth content renderer (d_z, d_img), applied as tanh(z M)
    render: Vec<f32>,
    /// per-style (gain, direction vector d_img, shift scalar)
    gains: [f32; 2],
    dirs: [Vec<f32>; 2],
}

impl StyleWorld {
    /// Deterministic world from a seed: concept anchors, the ground-truth
    /// renderer, and both style transforms.
    pub fn new(d_z: usize, d_img: usize, seed: u64) -> Self {
        let root = Rng::new(seed);
        let mut anchors = Vec::with_capacity(N_CONCEPTS);
        let mut ar = root.stream("anchors");
        for _ in 0..N_CONCEPTS {
            let mut a = vec![0.0f32; d_z];
            ar.fill_normal(&mut a, 0.0, 1.0);
            anchors.push(a);
        }
        let mut render = vec![0.0f32; d_z * d_img];
        root.stream("render")
            .fill_normal(&mut render, 0.0, 1.0 / (d_z as f32).sqrt());
        let mut dirs = [vec![0.0f32; d_img], vec![0.0f32; d_img]];
        root.stream("dir/bluefire").fill_normal(&mut dirs[0], 0.0, 1.0);
        root.stream("dir/paintings").fill_normal(&mut dirs[1], 0.0, 1.0);
        StyleWorld {
            d_z,
            d_img,
            anchors,
            render,
            gains: [0.6, 0.45],
            dirs,
        }
    }

    /// Sample a content latent for `concept`.
    pub fn sample_z(&self, concept: usize, rng: &mut Rng) -> Vec<f32> {
        let a = &self.anchors[concept % N_CONCEPTS];
        a.iter().map(|&x| x + 0.25 * rng.normal() as f32).collect()
    }

    /// Ground-truth base ("content") image for z.
    pub fn base_image(&self, z: &[f32]) -> Vec<f32> {
        let mut img = vec![0.0f32; self.d_img];
        for (j, img_j) in img.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for (i, &zi) in z.iter().enumerate() {
                acc += zi * self.render[i * self.d_img + j];
            }
            *img_j = acc.tanh();
        }
        img
    }

    fn style_ix(style: Style) -> usize {
        match style {
            Style::Bluefire => 0,
            Style::Paintings => 1,
        }
    }

    /// Apply a style to a base image.
    ///
    /// Styles are *multiplicative, content-coupled* modulations:
    /// `y_j = b_j·(1 + s·g·t_j) + 0.3·s·g·t_j` with `t = 0.7·tanh(dir)`.
    /// An elementwise modulation is a (near-)diagonal transform of image
    /// space — HIGH RANK, which is precisely the regime the paper argues
    /// sparse high-rank adapters capture and low-rank adapters cannot
    /// (§1, Kalajdzievski 2023).  It also couples style to content, so
    /// independently trained dense adapters interfere when summed (the
    /// concept-loss mechanism), while sparse supports barely collide.
    pub fn stylize(&self, base: &[f32], style: Style, strength: f32) -> Vec<f32> {
        let s = Self::style_ix(style);
        let g = strength * self.gains[s];
        base.iter()
            .zip(self.dirs[s].iter())
            .map(|(&b, &d)| {
                let t = 0.7 * d.tanh();
                b * (1.0 + g * t) + 0.3 * g * t
            })
            .collect()
    }

    /// Target for multi-style generation: both styles at half strength —
    /// "a koala in blue fire, painted" (paper Figs. 1/4/7).
    pub fn stylize_both(&self, base: &[f32]) -> Vec<f32> {
        let once = self.stylize(base, Style::Bluefire, 0.5);
        self.stylize(&once, Style::Paintings, 0.5)
    }

    /// Style-match component: how well does `img` reflect `style` applied
    /// to the content of z?
    fn match_score(&self, img: &[f32], target: &[f32]) -> f64 {
        let mse: f64 = img
            .iter()
            .zip(target.iter())
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / img.len() as f64;
        (-3.0 * mse).exp()
    }

    /// SPS — the HPSv2 proxy, in the paper's ~0-40 scale.
    ///
    /// style-match: distance to the styled ground truth;
    /// content-preservation: distance of the de-styled image to the base
    /// render (detects concept loss independent of style strength).
    pub fn sps(&self, img: &[f32], z: &[f32], style: Style, strength: f32) -> f64 {
        let base = self.base_image(z);
        let target = self.stylize(&base, style, strength);
        let style_match = self.match_score(img, &target);
        // de-style: invert the modulation at the nominal strength (detects
        // concept loss independent of style strength)
        let s = Self::style_ix(style);
        let g = strength * self.gains[s];
        let destyled: Vec<f32> = img
            .iter()
            .zip(self.dirs[s].iter())
            .map(|(&y, &d)| {
                let t = 0.7 * d.tanh();
                (y - 0.3 * g * t) / (1.0 + g * t).max(0.15)
            })
            .collect();
        let content = self.match_score(&destyled, &base);
        40.0 * (style_match * content).sqrt()
    }

    /// SPS against the dual-style target (multi-adapter evaluation).
    pub fn sps_multi(&self, img: &[f32], z: &[f32]) -> f64 {
        let base = self.base_image(z);
        let target = self.stylize_both(&base);
        let style_match = self.match_score(img, &target);
        let content = self.match_score(&base, &base); // = 1; content folded into target here
        40.0 * (style_match * content).sqrt()
    }
}

/// A (z, styled target) supervised pair set for adapter finetuning.
pub struct StyleDataset {
    /// The style this dataset supervises.
    pub style: Style,
    /// The world the pairs are rendered in.
    pub world: StyleWorld,
    seed: u64,
}

impl StyleDataset {
    /// Dataset for `style` in `world` (seed reserved for future
    /// subsampling; batches draw from the caller's rng).
    pub fn new(world: StyleWorld, style: Style, seed: u64) -> Self {
        StyleDataset { style, world, seed }
    }

    /// Sample a training batch: concepts limited to the style's train set.
    pub fn train_batch(&self, batch: usize, rng: &mut Rng) -> (Vec<f32>, Vec<f32>) {
        let range = self.style.train_concepts();
        self.batch_from_concepts(batch, rng, |r| {
            range.start + r.below(range.end - range.start)
        })
    }

    /// Validation batch over given concepts (`unseen=true` → held-out).
    pub fn eval_batch(
        &self,
        batch: usize,
        unseen: bool,
        rng: &mut Rng,
    ) -> (Vec<f32>, Vec<f32>) {
        let range = self.style.train_concepts();
        self.batch_from_concepts(batch, rng, move |r| {
            if unseen {
                // concepts outside the training range
                let mut c = r.below(N_CONCEPTS);
                while range.contains(&c) {
                    c = r.below(N_CONCEPTS);
                }
                c
            } else {
                range.start + r.below(range.end - range.start)
            }
        })
    }

    fn batch_from_concepts(
        &self,
        batch: usize,
        rng: &mut Rng,
        mut pick: impl FnMut(&mut Rng) -> usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let _ = self.seed;
        let (dz, dimg) = (self.world.d_z, self.world.d_img);
        let mut zs = Vec::with_capacity(batch * dz);
        let mut targets = Vec::with_capacity(batch * dimg);
        for _ in 0..batch {
            let c = pick(rng);
            let z = self.world.sample_z(c, rng);
            let base = self.world.base_image(&z);
            let styled = self.world.stylize(&base, self.style, 1.0);
            zs.extend_from_slice(&z);
            targets.extend_from_slice(&styled);
        }
        (zs, targets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn world() -> StyleWorld {
        StyleWorld::new(16, 48, 11)
    }

    #[test]
    fn base_image_deterministic_and_bounded() {
        let w = world();
        let mut rng = Rng::new(1);
        let z = w.sample_z(0, &mut rng);
        let a = w.base_image(&z);
        let b = w.base_image(&z);
        assert_eq!(a, b);
        assert!(a.iter().all(|&x| (-1.0..=1.0).contains(&x)));
    }

    #[test]
    fn perfect_styled_image_scores_high() {
        let w = world();
        let mut rng = Rng::new(2);
        let z = w.sample_z(1, &mut rng);
        let styled = w.stylize(&w.base_image(&z), Style::Bluefire, 1.0);
        let sps = w.sps(&styled, &z, Style::Bluefire, 1.0);
        assert!(sps > 39.0, "sps={sps}");
    }

    #[test]
    fn unstyled_image_scores_lower() {
        let w = world();
        let mut rng = Rng::new(3);
        let z = w.sample_z(2, &mut rng);
        let base = w.base_image(&z);
        let styled = w.stylize(&base, Style::Paintings, 1.0);
        let sps_styled = w.sps(&styled, &z, Style::Paintings, 1.0);
        let sps_base = w.sps(&base, &z, Style::Paintings, 1.0);
        assert!(sps_styled > sps_base + 1.0, "{sps_styled} vs {sps_base}");
    }

    #[test]
    fn wrong_content_scores_lower() {
        // concept-loss direction: styled image of a DIFFERENT concept
        let w = world();
        let mut rng = Rng::new(4);
        let z1 = w.sample_z(0, &mut rng);
        let z2 = w.sample_z(5, &mut rng);
        let right = w.stylize(&w.base_image(&z1), Style::Bluefire, 1.0);
        let wrong = w.stylize(&w.base_image(&z2), Style::Bluefire, 1.0);
        let s_right = w.sps(&right, &z1, Style::Bluefire, 1.0);
        let s_wrong = w.sps(&wrong, &z1, Style::Bluefire, 1.0);
        assert!(s_right > s_wrong + 3.0, "{s_right} vs {s_wrong}");
    }

    #[test]
    fn alpha_zero_is_base_model_target() {
        let w = world();
        let mut rng = Rng::new(5);
        let z = w.sample_z(3, &mut rng);
        let base = w.base_image(&z);
        let s0 = w.stylize(&base, Style::Bluefire, 0.0);
        assert_eq!(s0, base);
    }

    #[test]
    fn dataset_batches_shaped_and_deterministic_world() {
        let w = world();
        let ds = StyleDataset::new(w, Style::Bluefire, 7);
        let mut rng = Rng::new(6);
        let (z, t) = ds.train_batch(4, &mut rng);
        assert_eq!(z.len(), 4 * 16);
        assert_eq!(t.len(), 4 * 48);
    }

    #[test]
    fn unseen_eval_concepts_outside_train_range() {
        let w = world();
        let ds = StyleDataset::new(w.clone(), Style::Bluefire, 7);
        let range = Style::Bluefire.train_concepts();
        // brute-force check: unseen z's are far from train anchors
        let mut rng = Rng::new(8);
        let (zs, _) = ds.eval_batch(16, true, &mut rng);
        for chunk in zs.chunks(w.d_z) {
            // nearest anchor must be a held-out concept
            let mut best = (f32::MAX, 0usize);
            for (c, a) in w.anchors.iter().enumerate() {
                let d: f32 = chunk.iter().zip(a.iter()).map(|(x, y)| (x - y).powi(2)).sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            assert!(!range.contains(&best.1), "unseen batch drew train concept");
        }
    }

    #[test]
    fn multi_style_target_differs_from_single() {
        let w = world();
        let mut rng = Rng::new(9);
        let z = w.sample_z(4, &mut rng);
        let base = w.base_image(&z);
        let both = w.stylize_both(&base);
        let single = w.stylize(&base, Style::Bluefire, 1.0);
        let d: f32 = both.iter().zip(single.iter()).map(|(a, b)| (a - b).abs()).sum();
        assert!(d > 0.1);
    }
}
