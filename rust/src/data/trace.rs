//! Request-trace generation for the serving experiments (Appendix A/B):
//! streams of inference requests tagged with the adapter they need.

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Adapter name ("bluefire", "task/boolq", ...); empty = base model.
    pub adapter: String,
    /// Virtual arrival time (microseconds from trace start).
    pub arrival_us: u64,
    /// Seed for the request's payload (tokens / latent).
    pub payload_seed: u64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TracePattern {
    /// Each request picks an adapter uniformly — worst case for switching.
    UniformMix,
    /// Runs of the same adapter (length ~ `burst`), the mobile-app pattern
    /// the paper's rapid-switching story targets.
    Bursty { burst: usize },
    /// Strict rotation through adapters — adversarial for affinity
    /// scheduling, maximal switch count.
    RoundRobin,
}

/// Generate a trace of `n` requests over `adapters` with Poisson-ish
/// arrivals at `rate_per_sec`.
pub fn generate_trace(
    adapters: &[String],
    n: usize,
    pattern: TracePattern,
    rate_per_sec: f64,
    seed: u64,
) -> Vec<Request> {
    assert!(!adapters.is_empty());
    let mut rng = Rng::new(seed).stream("trace");
    let mut out = Vec::with_capacity(n);
    let mut t_us = 0u64;
    let mean_gap_us = 1e6 / rate_per_sec;
    let mut current = 0usize;
    let mut run_left = 0usize;
    for id in 0..n {
        let a = match pattern {
            TracePattern::UniformMix => rng.below(adapters.len()),
            TracePattern::RoundRobin => id % adapters.len(),
            TracePattern::Bursty { burst } => {
                if run_left == 0 {
                    current = rng.below(adapters.len());
                    run_left = 1 + rng.below(2 * burst);
                }
                run_left -= 1;
                current
            }
        };
        // exponential inter-arrival
        let gap = -mean_gap_us * (1.0 - rng.uniform()).ln();
        t_us += gap.max(1.0) as u64;
        out.push(Request {
            id: id as u64,
            adapter: adapters[a].clone(),
            arrival_us: t_us,
            payload_seed: rng.next_u64(),
        });
    }
    out
}

/// Number of adapter *switches* an in-order scan of the trace would incur —
/// the quantity SHiRA's scatter path makes cheap.
pub fn switch_count(trace: &[Request]) -> usize {
    trace
        .windows(2)
        .filter(|w| w[0].adapter != w[1].adapter)
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn names(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("a{i}")).collect()
    }

    #[test]
    fn trace_sorted_and_complete() {
        let t = generate_trace(&names(3), 100, TracePattern::UniformMix, 1000.0, 1);
        assert_eq!(t.len(), 100);
        assert!(t.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us));
        assert!(t.iter().all(|r| r.adapter.starts_with('a')));
    }

    #[test]
    fn round_robin_maximizes_switches() {
        let rr = generate_trace(&names(4), 100, TracePattern::RoundRobin, 1e3, 2);
        assert_eq!(switch_count(&rr), 99);
    }

    #[test]
    fn bursty_reduces_switches() {
        let b = generate_trace(&names(4), 400, TracePattern::Bursty { burst: 16 }, 1e3, 3);
        let u = generate_trace(&names(4), 400, TracePattern::UniformMix, 1e3, 3);
        assert!(
            switch_count(&b) * 2 < switch_count(&u),
            "bursty {} vs uniform {}",
            switch_count(&b),
            switch_count(&u)
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate_trace(&names(2), 50, TracePattern::UniformMix, 1e3, 9);
        let b = generate_trace(&names(2), 50, TracePattern::UniformMix, 1e3, 9);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.adapter, y.adapter);
            assert_eq!(x.arrival_us, y.arrival_us);
        }
    }

    #[test]
    fn uniform_mix_covers_all_adapters() {
        let t = generate_trace(&names(5), 200, TracePattern::UniformMix, 1e3, 4);
        let mut seen = std::collections::HashSet::new();
        for r in &t {
            seen.insert(r.adapter.clone());
        }
        assert_eq!(seen.len(), 5);
    }
}
