//! Request-trace generation for the serving experiments (Appendix A/B):
//! streams of inference requests, each carrying the [`Selection`] that
//! must be resident when its batch executes — base weights, one adapter,
//! or a weighted adapter set.

use crate::coordinator::selection::Selection;
use crate::util::rng::Rng;

/// One serving request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Monotonic request id within the trace.
    pub id: u64,
    /// What must be resident on the weights for this request: the base
    /// model, a single adapter, or a fused set (see [`Selection`]).
    pub selection: Selection,
    /// Virtual arrival time (microseconds from trace start).
    pub arrival_us: u64,
    /// Seed for the request's payload (tokens / latent).
    pub payload_seed: u64,
}

/// How a trace interleaves its selections.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TracePattern {
    /// Each request picks a selection uniformly — worst case for switching.
    UniformMix,
    /// Runs of the same selection (length ~ `burst`), the mobile-app
    /// pattern the paper's rapid-switching story targets.
    Bursty {
        /// Mean run length (actual runs are 1..2·burst).
        burst: usize,
    },
    /// Strict rotation through selections — adversarial for affinity
    /// scheduling, maximal switch count.
    RoundRobin,
    /// Bursty traffic from a large Zipf-popularity user population: each
    /// new burst belongs to one of `users` users drawn with probability
    /// ∝ 1/rankᔆ (s = [`ZIPF_EXPONENT`]), and every user maps to a fixed
    /// selection by a stable hash — the 10k-user serving regime the
    /// fleet scheduler targets.  A handful of head users dominate, so
    /// affinity routing has real structure to exploit while the long
    /// tail keeps cold switches coming.
    ZipfUsers {
        /// Distinct users (popularity ranks 1..=users).
        users: usize,
        /// Mean run length of one user's burst (runs are 1..2·burst).
        burst: usize,
    },
}

/// Zipf popularity exponent of [`TracePattern::ZipfUsers`].  Fixed (not a
/// field) so the pattern stays `Copy + Eq`; 1.1 is the classic web/cache
/// workload shape — a heavy head with a fat tail.
pub const ZIPF_EXPONENT: f64 = 1.1;

/// Cumulative Zipf distribution over ranks 1..=users (last entry 1.0).
fn zipf_cdf(users: usize) -> Vec<f64> {
    let mut cdf: Vec<f64> = Vec::with_capacity(users);
    let mut acc = 0.0f64;
    for rank in 1..=users {
        acc += 1.0 / (rank as f64).powf(ZIPF_EXPONENT);
        cdf.push(acc);
    }
    for c in &mut cdf {
        *c /= acc;
    }
    cdf
}

/// Stable 64-bit mix (splitmix64 finalizer) — maps a user id to its
/// fixed selection independent of trace length or seed.
fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Generate a trace of `n` requests over `selections` with Poisson-ish
/// arrivals at `rate_per_sec`.
///
/// # Examples
///
/// ```
/// use shira::coordinator::selection::Selection;
/// use shira::data::trace::{generate_trace, TracePattern};
///
/// let sels = vec![
///     Selection::Base,
///     Selection::single("style"),
///     Selection::set(&[("style", 0.5), ("task", 1.0)]),
/// ];
/// let trace = generate_trace(&sels, 12, TracePattern::RoundRobin, 1e4, 7);
/// assert_eq!(trace.len(), 12);
/// assert_eq!(trace[0].selection, Selection::Base);
/// assert_eq!(trace[1].selection, Selection::single("style"));
/// ```
pub fn generate_trace(
    selections: &[Selection],
    n: usize,
    pattern: TracePattern,
    rate_per_sec: f64,
    seed: u64,
) -> Vec<Request> {
    assert!(!selections.is_empty());
    let mut rng = Rng::new(seed).stream("trace");
    let mut out = Vec::with_capacity(n);
    let mut t_us = 0u64;
    let mean_gap_us = 1e6 / rate_per_sec;
    let mut current = 0usize;
    let mut run_left = 0usize;
    let cdf: Vec<f64> = match pattern {
        TracePattern::ZipfUsers { users, .. } => zipf_cdf(users.max(1)),
        _ => Vec::new(),
    };
    for id in 0..n {
        let a = match pattern {
            TracePattern::UniformMix => rng.below(selections.len()),
            TracePattern::RoundRobin => id % selections.len(),
            TracePattern::Bursty { burst } => {
                if run_left == 0 {
                    current = rng.below(selections.len());
                    run_left = 1 + rng.below(2 * burst);
                }
                run_left -= 1;
                current
            }
            TracePattern::ZipfUsers { burst, .. } => {
                if run_left == 0 {
                    // Draw a user by popularity rank, then map it to its
                    // fixed selection by a stable hash of the user id.
                    let u = rng.uniform();
                    let user = cdf.partition_point(|&c| c < u);
                    current = (mix64(user as u64 + 1) % selections.len() as u64) as usize;
                    run_left = 1 + rng.below(2 * burst.max(1));
                }
                run_left -= 1;
                current
            }
        };
        // exponential inter-arrival
        let gap = -mean_gap_us * (1.0 - rng.uniform()).ln();
        t_us += gap.max(1.0) as u64;
        out.push(Request {
            id: id as u64,
            selection: selections[a].clone(),
            arrival_us: t_us,
            payload_seed: rng.next_u64(),
        });
    }
    out
}

/// Rotating two-member set selections over `names`: member `i` paired
/// with member `i+1` (wrapping), the first at weight 1 and the second at
/// `weight` — the canonical synthetic fused-set workload shared by the
/// serve CLI, the serving bench and the e2e example.
///
/// # Examples
///
/// ```
/// use shira::data::trace::rotating_sets;
/// let names = vec!["a".to_string(), "b".to_string()];
/// let sets = rotating_sets(&names, 0.5);
/// assert_eq!(sets.len(), 2);
/// assert_eq!(sets[0].key(), "a@1+b@0.5");
/// ```
pub fn rotating_sets(names: &[String], weight: f32) -> Vec<Selection> {
    (0..names.len())
        .map(|i| {
            Selection::set(&[
                (names[i].as_str(), 1.0),
                (names[(i + 1) % names.len()].as_str(), weight),
            ])
        })
        .collect()
}

/// The canonical mixed-selection workload: base, every single, and
/// rotating two-member sets at half strength — one list exercising all
/// three routing arms per-request.
pub fn mixed_selections(names: &[String]) -> Vec<Selection> {
    let mut sels = vec![Selection::Base];
    sels.extend(Selection::singles(names));
    if names.len() > 1 {
        sels.extend(rotating_sets(names, 0.5));
    }
    sels
}

/// Number of selection *switches* an in-order scan of the trace would
/// incur — the quantity SHiRA's scatter path makes cheap.
pub fn switch_count(trace: &[Request]) -> usize {
    trace
        .windows(2)
        .filter(|w| w[0].selection != w[1].selection)
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn singles(n: usize) -> Vec<Selection> {
        (0..n).map(|i| Selection::single(&format!("a{i}"))).collect()
    }

    #[test]
    fn trace_sorted_and_complete() {
        let t = generate_trace(&singles(3), 100, TracePattern::UniformMix, 1000.0, 1);
        assert_eq!(t.len(), 100);
        assert!(t.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us));
        assert!(t.iter().all(|r| r.selection.key().starts_with('a')));
    }

    #[test]
    fn round_robin_maximizes_switches() {
        let rr = generate_trace(&singles(4), 100, TracePattern::RoundRobin, 1e3, 2);
        assert_eq!(switch_count(&rr), 99);
    }

    #[test]
    fn bursty_reduces_switches() {
        let b = generate_trace(&singles(4), 400, TracePattern::Bursty { burst: 16 }, 1e3, 3);
        let u = generate_trace(&singles(4), 400, TracePattern::UniformMix, 1e3, 3);
        assert!(
            switch_count(&b) * 2 < switch_count(&u),
            "bursty {} vs uniform {}",
            switch_count(&b),
            switch_count(&u)
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate_trace(&singles(2), 50, TracePattern::UniformMix, 1e3, 9);
        let b = generate_trace(&singles(2), 50, TracePattern::UniformMix, 1e3, 9);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.selection, y.selection);
            assert_eq!(x.arrival_us, y.arrival_us);
        }
    }

    #[test]
    fn uniform_mix_covers_all_selections() {
        let t = generate_trace(&singles(5), 200, TracePattern::UniformMix, 1e3, 4);
        let mut seen = std::collections::HashSet::new();
        for r in &t {
            seen.insert(r.selection.key());
        }
        assert_eq!(seen.len(), 5);
    }

    #[test]
    fn zipf_users_is_deterministic_and_head_heavy() {
        let sels = singles(8);
        let pat = TracePattern::ZipfUsers { users: 10_000, burst: 4 };
        let a = generate_trace(&sels, 500, pat, 1e4, 0xF1EE7);
        let b = generate_trace(&sels, 500, pat, 1e4, 0xF1EE7);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.selection, y.selection);
            assert_eq!(x.arrival_us, y.arrival_us);
            assert_eq!(x.payload_seed, y.payload_seed);
        }
        // Zipf head: the most popular selection dominates a uniform share.
        let mut counts = std::collections::HashMap::new();
        for r in &a {
            *counts.entry(r.selection.key()).or_insert(0usize) += 1;
        }
        let top = counts.values().copied().max().unwrap();
        assert!(
            top * sels.len() > 2 * a.len(),
            "head selection {top}/{} not dominant over uniform share",
            a.len()
        );
        // ...but the tail still shows up: several distinct selections.
        assert!(counts.len() >= 3, "only {} selections seen", counts.len());
    }

    #[test]
    fn zipf_users_bursts_reduce_switches() {
        let sels = singles(8);
        let bursty = generate_trace(
            &sels,
            400,
            TracePattern::ZipfUsers { users: 10_000, burst: 16 },
            1e4,
            11,
        );
        let choppy = generate_trace(
            &sels,
            400,
            TracePattern::ZipfUsers { users: 10_000, burst: 1 },
            1e4,
            11,
        );
        assert!(
            switch_count(&bursty) < switch_count(&choppy),
            "bursty {} vs choppy {}",
            switch_count(&bursty),
            switch_count(&choppy)
        );
    }

    #[test]
    fn mixed_selection_traces_generate() {
        let sels = vec![
            Selection::Base,
            Selection::single("a"),
            Selection::set(&[("a", 0.5), ("b", 1.0)]),
        ];
        let t = generate_trace(&sels, 60, TracePattern::Bursty { burst: 4 }, 1e3, 5);
        let keys: std::collections::HashSet<String> =
            t.iter().map(|r| r.selection.key()).collect();
        assert_eq!(keys.len(), 3, "all three selection kinds appear");
        assert!(switch_count(&t) >= 2);
    }
}
