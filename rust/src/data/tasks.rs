//! Synthetic commonsense-reasoning proxy suite (DESIGN.md §3).
//!
//! Eight task families stand in for BoolQ / PIQA / SIQA / OBQA / WinoGrande
//! / HellaSwag / ARC-e / ARC-c.  Each family:
//!
//! * draws its surface tokens from a disjoint "dialect" range, so adapters
//!   trained on different tasks acquire genuinely different circuits
//!   (the precondition for measuring multi-adapter concept interference);
//! * is a deterministic function of its tokens (100 % achievable accuracy);
//! * is evaluated as multiple-choice: the model's logit at the final
//!   position is compared across the candidate answer tokens.
//!
//! The paper trains on a 170K mixed corpus and evaluates per-task
//! (Tables 2-3), and trains per-task adapters for the fusion study
//! (Table 4); `mixture()` and `task_split()` mirror those two setups.

use crate::util::rng::Rng;

/// Padding token (outside every dialect).
pub const PAD: i32 = 0;
/// Premise/candidates separator token.
pub const SEP: i32 = 1;
/// Answer-slot marker: the model predicts at the position before it.
pub const QUERY: i32 = 2;
/// Boolean "yes" answer token.
pub const YES: i32 = 3;
/// Boolean "no" answer token.
pub const NO: i32 = 4;
const DIALECT_BASE: i32 = 16;
const DIALECT_SIZE: i32 = 28;

/// The eight synthetic task families, standing in for the paper's
/// commonsense suite (Tables 2-3).
///
/// # Examples
///
/// ```
/// use shira::data::tasks::Task;
/// assert_eq!(Task::parse("arc_e"), Some(Task::ArcEasy));
/// assert_eq!(Task::ArcEasy.name(), "arc_e");
/// assert_eq!(Task::parse("nope"), None);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Task {
    /// Entailment-style probe presence (BoolQ proxy).
    BoolQ,
    /// Goal/solution pairing (PIQA proxy).
    Piqa,
    /// Social permutation lookup (SIQA proxy).
    Siqa,
    /// Fact recall (OpenBookQA proxy).
    Obqa,
    /// Marker-selected coreference (WinoGrande proxy).
    Winogrande,
    /// Chain continuation (HellaSwag proxy).
    HellaSwag,
    /// Single-hop fact lookup (ARC-easy proxy).
    ArcEasy,
    /// Two-hop fact composition (ARC-challenge proxy).
    ArcChallenge,
}

/// Every task family, in the canonical report order.
pub const ALL_TASKS: [Task; 8] = [
    Task::BoolQ,
    Task::Piqa,
    Task::Siqa,
    Task::Obqa,
    Task::Winogrande,
    Task::HellaSwag,
    Task::ArcEasy,
    Task::ArcChallenge,
];

impl Task {
    /// Stable CLI / report name of the task.
    pub fn name(&self) -> &'static str {
        match self {
            Task::BoolQ => "boolq",
            Task::Piqa => "piqa",
            Task::Siqa => "siqa",
            Task::Obqa => "obqa",
            Task::Winogrande => "winogrande",
            Task::HellaSwag => "hellaswag",
            Task::ArcEasy => "arc_e",
            Task::ArcChallenge => "arc_c",
        }
    }

    /// Parse a task by its [`Self::name`].
    pub fn parse(s: &str) -> Option<Task> {
        ALL_TASKS.iter().copied().find(|t| t.name() == s)
    }

    fn index(&self) -> i32 {
        ALL_TASKS.iter().position(|t| t == self).unwrap() as i32
    }

    /// First token of this task's dialect range.
    fn base(&self) -> i32 {
        DIALECT_BASE + self.index() * DIALECT_SIZE
    }

    /// Dialect token #j (wrapped into the task's range).
    fn tok(&self, j: i32) -> i32 {
        self.base() + j.rem_euclid(DIALECT_SIZE)
    }
}

/// One multiple-choice example.
#[derive(Clone, Debug)]
pub struct Example {
    /// The task family that generated this example.
    pub task: Task,
    /// Input tokens, length = seq_len; the model predicts at the LAST slot.
    pub tokens: Vec<i32>,
    /// Gold answer token.
    pub answer: i32,
    /// Candidate answer tokens (includes `answer`).
    pub choices: Vec<i32>,
}

/// Deterministic per-task parameter tables (mappings, pairings,
/// permutations) derived from a seed so train and test agree.
fn task_table(task: Task, seed: u64, len: usize) -> Vec<i32> {
    let mut rng = Rng::new(seed).stream(&format!("table/{}", task.name()));
    let mut t: Vec<i32> = (0..len as i32).collect();
    rng.shuffle(&mut t);
    t
}

/// Generate one example.  `rng` drives the content; `seed` fixes the task's
/// hidden parameter tables (shared across all examples of a run).
pub fn generate(task: Task, seq_len: usize, seed: u64, rng: &mut Rng) -> Example {
    assert!(seq_len >= 12, "tasks need seq_len >= 12");
    let body = seq_len - 2; // room for QUERY marker + answer slot
    let mut tokens = vec![PAD; seq_len];
    let (answer, choices);
    match task {
        Task::BoolQ => {
            // Entailment-style: does the probe symbol occur in the premise?
            // (Associative/attention-friendly — parity-style counting is a
            // grokking-regime task, unlearnable at adapter scale.)
            let probe = task.tok(rng.below(8) as i32);
            tokens[0] = probe;
            tokens[1] = SEP;
            for slot in tokens.iter_mut().take(body).skip(2) {
                *slot = task.tok(8 + rng.below(20) as i32);
            }
            let present = rng.below(2) == 0;
            if present {
                let p = 2 + rng.below(body - 2);
                tokens[p] = probe;
            }
            answer = if present { YES } else { NO };
            choices = vec![YES, NO];
        }
        Task::Piqa => {
            // Pairing: which candidate is the partner of the goal token?
            let pairing = task_table(task, seed, 14);
            let g = rng.below(14) as i32;
            let correct = task.tok(14 + pairing[g as usize]);
            let mut wrong = task.tok(14 + pairing[(g as usize + 1) % 14]);
            if wrong == correct {
                wrong = task.tok(14 + pairing[(g as usize + 2) % 14]);
            }
            tokens[0] = task.tok(g);
            tokens[1] = SEP;
            let flip = rng.below(2) == 0;
            tokens[2] = if flip { correct } else { wrong };
            tokens[3] = if flip { wrong } else { correct };
            for slot in tokens.iter_mut().take(body).skip(4) {
                *slot = task.tok(rng.below(14) as i32);
            }
            answer = correct;
            choices = vec![correct, wrong];
        }
        Task::Siqa => {
            // Social permutation: answer = p(actor).
            let p = task_table(task, seed, 9);
            let actor = rng.below(9) as i32;
            tokens[0] = task.tok(actor);
            tokens[1] = SEP;
            for slot in tokens.iter_mut().take(body).skip(2) {
                *slot = task.tok(9 + rng.below(10) as i32);
            }
            answer = task.tok(19 + p[actor as usize] % 9);
            let d1 = task.tok(19 + (p[actor as usize] + 1) % 9);
            let d2 = task.tok(19 + (p[actor as usize] + 2) % 9);
            choices = vec![answer, d1, d2];
        }
        Task::Obqa => {
            // Fact recall: answer = table[key].
            let table = task_table(task, seed, 14);
            let key = rng.below(14) as i32;
            tokens[0] = task.tok(key);
            tokens[1] = SEP;
            for slot in tokens.iter_mut().take(body).skip(2) {
                *slot = task.tok(rng.below(14) as i32);
            }
            tokens[0] = task.tok(key); // key survives the filler
            answer = task.tok(14 + table[key as usize]);
            let d1 = task.tok(14 + (table[key as usize] + 3) % 14);
            choices = vec![answer, d1];
        }
        Task::Winogrande => {
            // Coreference: marker selects entity 1 or entity 2.
            let e1 = task.tok(rng.below(12) as i32);
            let mut e2 = task.tok(rng.below(12) as i32);
            if e2 == e1 {
                e2 = task.tok((e1 - task.base() + 1) % 12);
            }
            let m1 = task.tok(24);
            let m2 = task.tok(25);
            let pick_first = rng.below(2) == 0;
            tokens[0] = e1;
            tokens[1] = e2;
            tokens[2] = SEP;
            tokens[3] = if pick_first { m1 } else { m2 };
            for slot in tokens.iter_mut().take(body).skip(4) {
                *slot = task.tok(12 + rng.below(12) as i32);
            }
            answer = if pick_first { e1 } else { e2 };
            choices = vec![e1, e2];
        }
        Task::HellaSwag => {
            // Continuation: chain successor of the last premise token.
            let succ = task_table(task, seed, 20);
            let mut cur = rng.below(20) as i32;
            for slot in tokens.iter_mut().take(body) {
                *slot = task.tok(cur);
                cur = succ[cur as usize];
            }
            answer = task.tok(cur);
            let d1 = task.tok((cur + 5) % 20);
            let d2 = task.tok((cur + 11) % 20);
            choices = vec![answer, d1, d2];
        }
        Task::ArcEasy => {
            // Single-hop fact lookup: answer = table[key], with distractor
            // keys in the premise (the model must attend to position 0).
            let table = task_table(task, seed, 13);
            let a = rng.below(13) as i32;
            tokens[0] = task.tok(a);
            tokens[1] = SEP;
            for slot in tokens.iter_mut().take(body).skip(2) {
                *slot = task.tok(rng.below(13) as i32);
            }
            tokens[0] = task.tok(a);
            answer = task.tok(13 + table[a as usize]);
            let d1 = task.tok(13 + (table[a as usize] + 4) % 13);
            choices = vec![answer, d1];
        }
        Task::ArcChallenge => {
            // Two-hop composition: answer = tableB[tableA[a]] — harder than
            // arc_e (the paper's arc_c < arc_e accuracy ordering).
            let ta = task_table(task, seed, 11);
            let tb = task_table(task, seed ^ 0xC, 11);
            let a = rng.below(11) as i32;
            tokens[0] = task.tok(a);
            tokens[1] = SEP;
            for slot in tokens.iter_mut().take(body).skip(2) {
                *slot = task.tok(rng.below(11) as i32);
            }
            tokens[0] = task.tok(a);
            let hop = tb[ta[a as usize] as usize];
            answer = task.tok(11 + hop);
            let d1 = task.tok(11 + (hop + 3) % 11);
            choices = vec![answer, d1];
        }
    }
    tokens[body] = QUERY;
    tokens[seq_len - 1] = answer; // training target position (masked in eval)
    Example {
        task,
        tokens,
        answer,
        choices,
    }
}

/// A training batch in the shape the AOT train steps expect.
#[derive(Clone, Debug)]
pub struct Batch {
    /// (B, T) input tokens.
    pub x: Vec<i32>,
    /// (B, T) next-token targets.
    pub y: Vec<i32>,
    /// (B, T) loss mask (answer position only for task batches).
    pub mask: Vec<f32>,
    /// The examples the batch was packed from (empty for pretraining).
    pub examples: Vec<Example>,
}

/// Pack examples into a next-token-prediction batch: the model must place
/// the answer token at the final position; loss is masked to that slot.
pub fn pack_batch(examples: &[Example], seq_len: usize) -> Batch {
    let b = examples.len();
    let mut x = vec![PAD; b * seq_len];
    let mut y = vec![PAD; b * seq_len];
    let mut mask = vec![0.0f32; b * seq_len];
    for (i, ex) in examples.iter().enumerate() {
        assert_eq!(ex.tokens.len(), seq_len);
        // inputs: tokens with the answer slot blanked to QUERY
        for t in 0..seq_len {
            x[i * seq_len + t] = if t == seq_len - 1 { QUERY } else { ex.tokens[t] };
        }
        // next-token targets: shift left; only the answer position scores.
        for t in 0..seq_len - 1 {
            y[i * seq_len + t] = ex.tokens[t + 1];
        }
        y[i * seq_len + (seq_len - 2)] = ex.answer;
        mask[i * seq_len + (seq_len - 2)] = 1.0;
    }
    Batch {
        x,
        y,
        mask,
        examples: examples.to_vec(),
    }
}

/// Sample a batch from a task mixture (Tables 2-3 training setup).
pub fn mixture_batch(
    tasks: &[Task],
    batch: usize,
    seq_len: usize,
    seed: u64,
    rng: &mut Rng,
) -> Batch {
    let examples: Vec<Example> = (0..batch)
        .map(|_| {
            let t = *rng.choose(tasks);
            generate(t, seq_len, seed, rng)
        })
        .collect();
    pack_batch(&examples, seq_len)
}

/// Fixed evaluation set for one task (disjoint stream from training).
pub fn eval_set(task: Task, n: usize, seq_len: usize, seed: u64) -> Vec<Example> {
    let mut rng = Rng::new(seed).stream(&format!("eval/{}", task.name()));
    (0..n).map(|_| generate(task, seq_len, seed, &mut rng)).collect()
}

/// Generic "pretraining" stream: bigram chains over the whole vocab, so the
/// base model learns token statistics but NO task circuits.
pub fn pretrain_batch(
    vocab: usize,
    batch: usize,
    seq_len: usize,
    rng: &mut Rng,
) -> Batch {
    let mut x = vec![0i32; batch * seq_len];
    let mut y = vec![0i32; batch * seq_len];
    let mut mask = vec![0.0f32; batch * seq_len];
    for i in 0..batch {
        let mut cur = rng.below(vocab) as i32;
        for t in 0..seq_len {
            x[i * seq_len + t] = cur;
            // bigram successor: deterministic mix + noise
            let next = if rng.below(4) == 0 {
                rng.below(vocab) as i32
            } else {
                ((cur as usize * 31 + 17) % vocab) as i32
            };
            if t + 1 < seq_len {
                y[i * seq_len + t] = next;
                mask[i * seq_len + t] = 1.0;
            }
            cur = next;
        }
    }
    Batch {
        x,
        y,
        mask,
        examples: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dialects_are_disjoint() {
        for (i, a) in ALL_TASKS.iter().enumerate() {
            for b in ALL_TASKS.iter().skip(i + 1) {
                let ra = a.base()..a.base() + DIALECT_SIZE;
                let rb = b.base()..b.base() + DIALECT_SIZE;
                assert!(ra.end <= rb.start || rb.end <= ra.start);
            }
        }
        // all dialects fit a 256 vocab
        assert!(DIALECT_BASE + 8 * DIALECT_SIZE <= 256);
    }

    #[test]
    fn examples_well_formed() {
        let mut rng = Rng::new(1);
        for task in ALL_TASKS {
            for _ in 0..50 {
                let ex = generate(task, 32, 7, &mut rng);
                assert_eq!(ex.tokens.len(), 32);
                assert!(ex.choices.contains(&ex.answer), "{task:?}");
                assert!(ex.choices.len() >= 2);
                // all choices distinct
                let mut c = ex.choices.clone();
                c.sort_unstable();
                c.dedup();
                assert_eq!(c.len(), ex.choices.len(), "{task:?}");
                assert!(ex.tokens.iter().all(|&t| (0..256).contains(&t)));
            }
        }
    }

    #[test]
    fn answers_are_deterministic_functions() {
        // Same content stream + same table seed => same answers.
        for task in ALL_TASKS {
            let mut r1 = Rng::new(5);
            let mut r2 = Rng::new(5);
            for _ in 0..20 {
                let e1 = generate(task, 32, 9, &mut r1);
                let e2 = generate(task, 32, 9, &mut r2);
                assert_eq!(e1.tokens, e2.tokens);
                assert_eq!(e1.answer, e2.answer);
            }
        }
    }

    #[test]
    fn table_seed_changes_mappings() {
        // Different hidden-table seeds give different pairings (PIQA).
        let mut found_diff = false;
        for trial in 0..10 {
            let mut r1 = Rng::new(100 + trial);
            let mut r2 = r1.clone();
            let e1 = generate(Task::Piqa, 32, 1, &mut r1);
            let e2 = generate(Task::Piqa, 32, 2, &mut r2);
            if e1.answer != e2.answer {
                found_diff = true;
                break;
            }
        }
        assert!(found_diff);
    }

    #[test]
    fn pack_batch_masks_answer_slot_only() {
        let mut rng = Rng::new(2);
        let exs: Vec<Example> =
            (0..4).map(|_| generate(Task::ArcEasy, 32, 3, &mut rng)).collect();
        let b = pack_batch(&exs, 32);
        assert_eq!(b.x.len(), 4 * 32);
        for i in 0..4 {
            let row = &b.mask[i * 32..(i + 1) * 32];
            assert_eq!(row.iter().sum::<f32>(), 1.0);
            assert_eq!(row[30], 1.0);
            assert_eq!(b.y[i * 32 + 30], exs[i].answer);
            // the answer token never leaks into the input
            assert_eq!(b.x[i * 32 + 31], QUERY);
        }
    }

    #[test]
    fn mixture_covers_tasks() {
        let mut rng = Rng::new(3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..30 {
            let b = mixture_batch(&ALL_TASKS, 8, 32, 1, &mut rng);
            for e in &b.examples {
                seen.insert(e.task);
            }
        }
        assert_eq!(seen.len(), 8);
    }

    #[test]
    fn eval_set_is_stable() {
        let a = eval_set(Task::BoolQ, 10, 32, 42);
        let b = eval_set(Task::BoolQ, 10, 32, 42);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.tokens, y.tokens);
        }
    }

    #[test]
    fn pretrain_batch_shapes() {
        let mut rng = Rng::new(4);
        let b = pretrain_batch(256, 8, 32, &mut rng);
        assert_eq!(b.x.len(), 8 * 32);
        assert!(b.mask.iter().sum::<f32>() > 0.0);
        assert!(b.x.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn boolq_parity_is_learnable_signal() {
        // sanity: YES and NO both occur
        let mut rng = Rng::new(6);
        let mut yes = 0;
        let mut no = 0;
        for _ in 0..200 {
            let e = generate(Task::BoolQ, 32, 1, &mut rng);
            if e.answer == YES {
                yes += 1;
            } else {
                no += 1;
            }
        }
        assert!(yes > 20 && no > 20, "yes={yes} no={no}");
    }
}
