//! Seeded synthetic workload construction shared by the CLI (`shira
//! serve`), the serving/fleet benches, and the fleet/chaos tests — one
//! implementation so every consumer replays the *identical* adapters and
//! trace from one seed instead of each re-rolling its own zoo inline.
//!
//! Two zoo flavors:
//!
//! * **Manifest-backed** ([`synth_shira_adapter`] / [`synth_lora_adapter`]):
//!   adapters shaped by a model's [`ModelMeta`] segments, for serving
//!   against real PJRT artifacts.
//! * **Toy** ([`toy_base`] / [`toy_shira_zoo`]): square `wq`/`wk`
//!   tensors of a given dim, artifact-free — what the fleet determinism
//!   harness, the fleet bench gate, and the chaos tests drive in CI.
//!
//! Adapter content depends only on `(seed, name)` — each adapter draws
//! from its own named [`Rng`] stream — so adding or reordering zoo
//! members never perturbs the others.

use crate::adapter::sparse::SparseDelta;
use crate::adapter::{LoraAdapter, LoraTensor, ShiraAdapter};
use crate::coordinator::selection::Selection;
use crate::data::trace::{generate_trace, Request, TracePattern};
use crate::model::tensor::Tensor2;
use crate::model::weights::WeightStore;
use crate::runtime::manifest::ModelMeta;
use crate::util::rng::Rng;

/// User-population size of the canonical fleet trace ([`fleet_trace`]) —
/// the "10k concurrent users" regime the affinity scheduler targets.
pub const FLEET_TRACE_USERS: usize = 10_000;

/// Names `adapter0..adapterN-1` — the zoo naming every consumer shares.
pub fn adapter_names(n: usize) -> Vec<String> {
    (0..n).map(|i| format!("adapter{i}")).collect()
}

/// Per-adapter RNG: one named stream per `(seed, name)` pair.
fn adapter_rng(seed: u64, name: &str) -> Rng {
    Rng::new(seed).stream(&format!("synth/{name}"))
}

/// One synthetic SHiRA adapter shaped by `meta`'s SHiRA segments: `k`
/// random sparse entries per target, N(0, 0.01) values.
pub fn synth_shira_adapter(meta: &ModelMeta, name: &str, seed: u64) -> ShiraAdapter {
    let mut rng = adapter_rng(seed, name);
    let tensors = meta
        .shira
        .iter()
        .map(|seg| {
            let idx = rng.sample_indices(seg.numel(), seg.k);
            let mut d = vec![0.0f32; seg.k];
            rng.fill_normal(&mut d, 0.0, 0.01);
            (
                seg.name.clone(),
                SparseDelta::new(seg.shape.0, seg.shape.1, idx, d),
            )
        })
        .collect();
    ShiraAdapter {
        name: name.to_string(),
        strategy: "rand".into(),
        tensors,
    }
}

/// One synthetic LoRA adapter shaped by `meta`'s LoRA segments: rank-r
/// factors with N(0, 0.01) entries at `scale` (the manifest's
/// `lora_scale`).
pub fn synth_lora_adapter(meta: &ModelMeta, name: &str, scale: f32, seed: u64) -> LoraAdapter {
    let mut rng = adapter_rng(seed, name);
    let tensors = meta
        .lora
        .iter()
        .map(|seg| {
            let mut a = Tensor2::zeros(seg.shape.0, seg.rank);
            let mut b = Tensor2::zeros(seg.rank, seg.shape.1);
            rng.fill_normal(&mut a.data, 0.0, 0.01);
            rng.fill_normal(&mut b.data, 0.0, 0.01);
            LoraTensor {
                target: seg.name.clone(),
                a,
                b,
            }
        })
        .collect();
    LoraAdapter {
        name: name.to_string(),
        scale,
        tensors,
    }
}

/// Artifact-free base weights: square `wq`/`wk` tensors of `dim`.
pub fn toy_base(dim: usize, seed: u64) -> WeightStore {
    WeightStore::init(
        &[("wq".into(), vec![dim, dim]), ("wk".into(), vec![dim, dim])],
        seed,
    )
}

/// Artifact-free SHiRA zoo over [`toy_base`]'s targets: `nnz` sparse
/// entries per target with N(0, 0.5) values — visible deviations, so
/// bit-identity checks catch any torn byte.
pub fn toy_shira_zoo(dim: usize, names: &[String], nnz: usize, seed: u64) -> Vec<ShiraAdapter> {
    names
        .iter()
        .map(|name| {
            let mut rng = adapter_rng(seed, name);
            let mut mk = |rng: &mut Rng| {
                let idx = rng.sample_indices(dim * dim, nnz);
                let mut d = vec![0.0; nnz];
                rng.fill_normal(&mut d, 0.0, 0.5);
                SparseDelta::new(dim, dim, idx, d)
            };
            ShiraAdapter {
                name: name.clone(),
                strategy: "rand".into(),
                tensors: vec![("wq".into(), mk(&mut rng)), ("wk".into(), mk(&mut rng))],
            }
        })
        .collect()
}

/// The canonical bursty 10k-user Zipf trace
/// ([`TracePattern::ZipfUsers`], [`FLEET_TRACE_USERS`] users, 10k req/s)
/// over `selections` — the ONE trace constructor the fleet tests, the
/// `bench_serving` fleet scenario, and `shira serve --pattern zipf` all
/// call, so a seed printed by any of them replays bit-identically in the
/// others.
pub fn fleet_trace(
    selections: &[Selection],
    n: usize,
    burst: usize,
    seed: u64,
) -> Vec<Request> {
    generate_trace(
        selections,
        n,
        TracePattern::ZipfUsers {
            users: FLEET_TRACE_USERS,
            burst,
        },
        1e4,
        seed,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_content_depends_only_on_seed_and_name() {
        let names = adapter_names(3);
        let a = toy_shira_zoo(32, &names, 50, 7);
        // Same (seed, name) → same adapter, regardless of zoo shape.
        let solo = toy_shira_zoo(32, &names[1..2], 50, 7);
        assert_eq!(a[1], solo[0]);
        // Different seed → different content.
        let b = toy_shira_zoo(32, &names, 50, 8);
        assert_ne!(a[0], b[0]);
    }

    #[test]
    fn fleet_trace_replays_from_one_seed() {
        let sels = Selection::singles(&adapter_names(4));
        let t1 = fleet_trace(&sels, 200, 4, 0xABCD);
        let t2 = fleet_trace(&sels, 200, 4, 0xABCD);
        assert_eq!(t1.len(), t2.len());
        for (x, y) in t1.iter().zip(t2.iter()) {
            assert_eq!(x.selection, y.selection);
            assert_eq!(x.arrival_us, y.arrival_us);
            assert_eq!(x.payload_seed, y.payload_seed);
        }
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(adapter_names(2), vec!["adapter0", "adapter1"]);
    }
}
