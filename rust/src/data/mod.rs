//! Synthetic workloads: commonsense-proxy tasks (S11), style-transfer proxy
//! (S12), serving request traces, and the seeded zoo/trace synthesis
//! shared by the CLI, benches and tests.

pub mod style;
pub mod synth;
pub mod tasks;
pub mod trace;
