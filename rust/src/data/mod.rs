//! Synthetic workloads: commonsense-proxy tasks (S11), style-transfer proxy
//! (S12), and serving request traces.

pub mod style;
pub mod tasks;
pub mod trace;
