//! Minimal JSON substrate (parser + writer) — no serde in the offline
//! vendor set, so the manifest/config/report plumbing is built here.
//!
//! Supports the full JSON grammar minus exotic escapes (\u surrogate pairs
//! are decoded), preserves object key order, and keeps numbers as f64
//! (adequate: the manifest's largest integers are < 2^40).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.  Objects preserve key order (a `Vec`, not a map),
/// numbers are f64.
///
/// # Examples
///
/// ```
/// use shira::util::json::{self, Json};
///
/// let j = json::parse(r#"{"dim": 64, "name": "llama"}"#).unwrap();
/// assert_eq!(j.get("dim").and_then(Json::as_usize), Some(64));
/// assert_eq!(j.path("name").and_then(Json::as_str), Some("llama"));
/// ```
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (kept as f64; manifest integers are < 2^40).
    Num(f64),
    /// A string.
    Str(String),
    /// An array of values.
    Arr(Vec<Json>),
    /// An object as (key, value) pairs in source order.
    Obj(Vec<(String, Json)>),
}

/// Parse failure with the byte offset where it happened.
#[derive(Debug)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset into the source text.
    pub pos: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // -- accessors -------------------------------------------------------

    /// Object field lookup (None on non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that traverses a path like "models.llama.vocab".
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// The (key, value) pairs in source order, if this is an object.
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(kvs) => Some(kvs),
            _ => None,
        }
    }

    /// Shape helper: array of numbers -> Vec<usize>.
    pub fn as_shape(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|x| x.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    // -- construction helpers -------------------------------------------

    /// Build an object from (key, value) pairs.
    pub fn obj(kvs: Vec<(&str, Json)>) -> Json {
        Json::Obj(kvs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a number.
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// Build a string.
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // -- serialization ---------------------------------------------------

    /// Serialize with newlines and two-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Serialize without any whitespace.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{}", x);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !xs.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !kvs.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// Parse a complete JSON document (trailing characters are an error).
pub fn parse(src: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        b: src.as_bytes(),
        i: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.i,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                // surrogate pair
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                let hi = cp as u32;
                                let lo = lo as u32;
                                char::from_u32(
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00),
                                )
                                .ok_or_else(|| self.err("bad surrogate"))?
                            } else {
                                char::from_u32(cp as u32)
                                    .ok_or_else(|| self.err("bad codepoint"))?
                            };
                            out.push(c);
                            continue; // hex4 already advanced
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u16::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            kvs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Convenience: a sorted map view of an object (for canonical comparisons).
pub fn to_map(j: &Json) -> BTreeMap<String, Json> {
    match j {
        Json::Obj(kvs) => kvs.iter().cloned().collect(),
        _ => BTreeMap::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.path("c").unwrap().as_str().unwrap(), "x");
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parse_escapes() {
        let j = parse(r#""a\nb\t\"q\" A 😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"q\" A 😀");
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let src = r#"{"x": 1, "y": [true, null, "s"], "z": {"k": -2.5}}"#;
        let j = parse(src).unwrap();
        for text in [j.to_string_pretty(), j.to_string_compact()] {
            assert_eq!(parse(&text).unwrap(), j);
        }
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,", "{\"a\" 1}", "tru", "1 2", "\"unterminated"] {
            assert!(parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn key_order_preserved() {
        let j = parse(r#"{"z": 1, "a": 2}"#).unwrap();
        let keys: Vec<_> = j.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a"]);
    }

    #[test]
    fn as_shape() {
        let j = parse("[8, 32, 256]").unwrap();
        assert_eq!(j.as_shape().unwrap(), vec![8, 32, 256]);
        assert!(parse("[1, \"x\"]").unwrap().as_shape().is_none());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let j = parse(&text).expect("manifest parses");
            assert!(j.get("artifacts").is_some());
            assert!(j.path("models.llama.vocab").unwrap().as_usize().unwrap() > 0);
        }
    }
}
