//! Thread-pool substrate (no tokio in the offline vendor set).
//!
//! A fixed-size worker pool over an MPMC channel built from Mutex+Condvar.
//! The serving coordinator uses it for request execution; `scope`-free
//! (jobs are 'static) with a `join` barrier for batch workloads.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    /// Signalled when in-flight + queued returns to zero.
    idle: Condvar,
    pending: AtomicUsize,
    shutdown: Mutex<bool>,
}

pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n_threads: usize) -> Self {
        let n = n_threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            idle: Condvar::new(),
            pending: AtomicUsize::new(0),
            shutdown: Mutex::new(false),
        });
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let sh = Arc::clone(&shared);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("shira-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker"),
            );
        }
        ThreadPool { shared, workers }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.pending.fetch_add(1, Ordering::SeqCst);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Box::new(f));
        }
        self.shared.available.notify_one();
    }

    /// Block until every enqueued job has finished.
    pub fn join(&self) {
        let guard = self.shared.queue.lock().unwrap();
        let _unused = self
            .shared
            .idle
            .wait_while(guard, |_| self.shared.pending.load(Ordering::SeqCst) != 0)
            .unwrap();
    }

    /// Run `f` over items in parallel, preserving order of results.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            self.execute(move || {
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
            });
        }
        self.join();
        Arc::try_unwrap(results)
            .ok()
            .expect("all workers done")
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|o| o.expect("job completed"))
            .collect()
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break Some(job);
                }
                if *sh.shutdown.lock().unwrap() {
                    break None;
                }
                q = sh.available.wait(q).unwrap();
            }
        };
        match job {
            None => return,
            Some(job) => {
                job();
                if sh.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
                    let _q = sh.queue.lock().unwrap();
                    sh.idle.notify_all();
                }
            }
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<u64>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn join_with_no_jobs_returns() {
        let pool = ThreadPool::new(2);
        pool.join();
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let out = pool.map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        pool.join();
        drop(pool);
    }
}
