//! Thread-pool substrate (no tokio in the offline vendor set).
//!
//! A fixed-size worker pool over an MPMC channel built from Mutex+Condvar,
//! plus a **scoped parallel-for** primitive (`scoped_for`) that runs
//! closures borrowing the caller's stack — no `'static` bound, no per-item
//! `Arc<Mutex<..>>`.  The switch engine's scatter/restore hot paths and the
//! tiled LoRA fuse baseline are built on it (DESIGN.md §4–§5).
//!
//! `scoped_for` is starvation-proof: the calling thread participates in the
//! work-stealing loop, so the region completes even when every pool worker
//! is pinned by unrelated long-running jobs.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A raw pointer that may cross threads.  Safety is the *user's* contract:
/// every use must guarantee disjoint access (each index touched by exactly
/// one task) and that the pointee outlives the parallel region.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Wrap a raw pointer for cross-thread use (see the type contract).
    pub fn new(p: *mut T) -> Self {
        SendPtr(p)
    }

    /// The wrapped pointer.
    pub fn get(self) -> *mut T {
        self.0
    }
}

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    /// Signalled when in-flight + queued returns to zero.
    idle: Condvar,
    pending: AtomicUsize,
    shutdown: Mutex<bool>,
}

/// Fixed-size worker pool with fire-and-forget jobs ([`Self::execute`]),
/// an ordered parallel map ([`Self::map`]) and a starvation-proof scoped
/// parallel-for ([`Self::scoped_for`]).
///
/// # Examples
///
/// ```
/// use shira::util::threadpool::ThreadPool;
///
/// let pool = ThreadPool::new(4);
/// let offset = 10u64; // borrowed from the stack: no 'static bound
/// let out = pool.map(vec![1u64, 2, 3], |x| x + offset);
/// assert_eq!(out, vec![11, 12, 13]);
/// ```
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    /// Per-shard affinity hints for `scoped_for` (see
    /// [`Self::set_affinity_hints`]).  Default off.
    affinity: AtomicBool,
}

/// One contiguous stripe of task indices `[next₀, hi)` owned by one
/// region participant in affinity mode.  Claims use the same
/// fetch-add-and-overshoot protocol as the single shared counter.
struct StripeCtl {
    next: AtomicUsize,
    hi: usize,
}

/// Control block for one `scoped_for` region.
struct ScopeCtl {
    /// Next unclaimed task index (claims may overshoot `n`).
    next: AtomicUsize,
    /// Affinity mode: one contiguous stripe per participant; empty means
    /// single-counter mode.  Participant `p` drains stripe `p` first,
    /// then steals from `(p+1) % len`, `(p+2) % len`, … — exactly-once
    /// holds because every index belongs to exactly one stripe.
    stripes: Vec<StripeCtl>,
    /// Workers currently inside the region body (borrowing the closure).
    borrowers: AtomicUsize,
    /// Set by the caller once its own drive loop exits; late-starting
    /// helpers observe it and never touch the (now possibly dead) closure.
    closed: AtomicBool,
    /// A task body panicked (on any thread); re-raised by the caller.
    panicked: AtomicBool,
    /// Stringified payload of the first captured panic (for [`ScopeFault`]).
    fault: Mutex<Option<String>>,
    exit_mtx: Mutex<()>,
    exit_cv: Condvar,
}

impl ScopeCtl {
    /// Record a panic payload (first writer wins) and raise the flag.
    fn record_panic(&self, payload: &(dyn std::any::Any + Send)) {
        self.panicked.store(true, Ordering::SeqCst);
        let mut g = self.fault.lock().unwrap_or_else(|p| p.into_inner());
        if g.is_none() {
            *g = Some(payload_message(payload));
        }
    }
}

/// Best-effort stringification of a panic payload (`&str` and `String`
/// payloads — the overwhelmingly common cases — pass through verbatim).
fn payload_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Failure report from [`ThreadPool::try_scoped_for`]: at least one task
/// body panicked.  The region has still waited for every in-flight task
/// before returning, so caller-borrowed state is safe to inspect and
/// repair — this is the contract the coordinator's transactional weight
/// rollback is built on.
#[derive(Debug)]
pub struct ScopeFault {
    /// Stringified payload of the first captured panic.
    pub message: String,
}

impl std::fmt::Display for ScopeFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "scoped task panicked: {}", self.message)
    }
}

impl std::error::Error for ScopeFault {}

impl ScopeCtl {
    fn notify_exit(&self) {
        // Never poisoned by user code (the lock only guards the handoff),
        // but stay non-panicking: this runs from Drop during unwinding.
        let _g = self.exit_mtx.lock().unwrap_or_else(|p| p.into_inner());
        self.exit_cv.notify_all();
    }
}

/// Decrements the borrower count on drop — helper exit stays accounted
/// even if the task body panics.
struct BorrowerExit(Arc<ScopeCtl>);

impl Drop for BorrowerExit {
    fn drop(&mut self) {
        if self.0.borrowers.fetch_sub(1, Ordering::SeqCst) == 1 {
            self.0.notify_exit();
        }
    }
}

/// Caller-side guard: fences off late helpers and waits for in-flight
/// borrowers.  Runs on normal exit AND on unwind, so the closure can never
/// die while a worker still holds a pointer into it.
struct CallerExit(Arc<ScopeCtl>);

impl Drop for CallerExit {
    fn drop(&mut self) {
        self.0.closed.store(true, Ordering::SeqCst);
        let mut g = self.0.exit_mtx.lock().unwrap_or_else(|p| p.into_inner());
        while self.0.borrowers.load(Ordering::SeqCst) != 0 {
            g = self
                .0
                .exit_cv
                .wait(g)
                .unwrap_or_else(|p| p.into_inner());
        }
    }
}

/// Type-erased pointer to the region body.  The caller blocks until every
/// borrower has exited, so the pointee outlives all dereferences.
#[derive(Clone, Copy)]
struct BodyPtr(*const (dyn Fn(usize) + Sync + 'static));

unsafe impl Send for BodyPtr {}
unsafe impl Sync for BodyPtr {}

fn drive(body: BodyPtr, ctl: &ScopeCtl, me: usize, n: usize) {
    // SAFETY: the scoped_for caller keeps the closure alive until all
    // borrowers exit; borrower registration guards this call.
    let f = unsafe { &*body.0 };
    if ctl.stripes.is_empty() {
        loop {
            let i = ctl.next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            f(i);
        }
    } else {
        // Affinity mode: drain our own stripe, then steal from the others
        // in ring order so finished participants still help stragglers.
        let len = ctl.stripes.len();
        for off in 0..len {
            let s = &ctl.stripes[(me + off) % len];
            loop {
                let i = s.next.fetch_add(1, Ordering::Relaxed);
                if i >= s.hi {
                    break;
                }
                f(i);
            }
        }
    }
}

impl ThreadPool {
    /// Pool with `n_threads` workers (minimum 1).
    pub fn new(n_threads: usize) -> Self {
        // Probe the kernel dispatch ladder once, at pool construction, so
        // the first hot-path apply never pays the env lookup and every
        // engine built over this pool sees one settled answer
        // (DESIGN.md §15).
        crate::adapter::kernel::active_dispatch();
        let n = n_threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            idle: Condvar::new(),
            pending: AtomicUsize::new(0),
            shutdown: Mutex::new(false),
        });
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let sh = Arc::clone(&shared);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("shira-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker"),
            );
        }
        ThreadPool {
            shared,
            workers,
            affinity: AtomicBool::new(false),
        }
    }

    /// A pool sized to the host (`available_parallelism`, min 1).
    pub fn host_sized() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        ThreadPool::new(n)
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Enable or disable per-shard affinity hints.  When on, `scoped_for`
    /// partitions task indices into one contiguous stripe per participant
    /// and each participant drains its own stripe before stealing from the
    /// others in ring order, so repeated regions tend to revisit the same
    /// weight rows on the same thread (warmer caches) at the cost of
    /// slightly less even load when task costs are skewed.  Purely a
    /// scheduling hint: exactly-once execution and bit-identical results
    /// hold either way.  Default off; flip with `--affinity`.
    pub fn set_affinity_hints(&self, on: bool) {
        self.affinity.store(on, Ordering::Relaxed);
    }

    /// Whether per-shard affinity hints are enabled.
    pub fn affinity_hints(&self) -> bool {
        self.affinity.load(Ordering::Relaxed)
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.pending.fetch_add(1, Ordering::SeqCst);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Box::new(f));
        }
        self.shared.available.notify_one();
    }

    /// Block until every enqueued job has finished.
    pub fn join(&self) {
        let guard = self.shared.queue.lock().unwrap();
        let _unused = self
            .shared
            .idle
            .wait_while(guard, |_| self.shared.pending.load(Ordering::SeqCst) != 0)
            .unwrap();
    }

    /// Scoped parallel-for: run `f(0)..f(n_tasks-1)` across the pool.
    ///
    /// * `f` may borrow the caller's stack — there is no `'static` bound.
    /// * Task indices are claimed from a shared atomic counter, so there is
    ///   no per-item allocation or locking on the hot path.
    /// * The calling thread drives tasks too; if every pool worker is busy
    ///   (or the pool is saturated by other scopes), the region still
    ///   completes — helpers that start late simply find no work.
    ///
    /// Returns only after every claimed task has finished.  Panics with a
    /// fixed message when any task body panicked (on a worker or on the
    /// calling thread); use [`Self::try_scoped_for`] to observe the
    /// failure as a value instead.
    pub fn scoped_for<F: Fn(usize) + Sync>(&self, n_tasks: usize, f: F) {
        if self.try_scoped_for(n_tasks, f).is_err() {
            panic!("scoped_for: a task panicked on a pool worker");
        }
    }

    /// Fallible [`Self::scoped_for`]: identical dispatch and borrowing
    /// rules, but a panicking task body surfaces as `Err(ScopeFault)`
    /// (carrying the first panic's message) instead of unwinding the
    /// caller.  On `Err`, some task indices may never have run — but the
    /// region has fully quiesced: no worker still borrows the closure or
    /// any caller-owned buffer, so the caller can roll back shared state
    /// mid-mutation safely.
    pub fn try_scoped_for<F: Fn(usize) + Sync>(
        &self,
        n_tasks: usize,
        f: F,
    ) -> Result<(), ScopeFault> {
        if n_tasks == 0 {
            return Ok(());
        }
        let helpers = self.threads().min(n_tasks.saturating_sub(1));
        if helpers == 0 {
            return match catch_unwind(AssertUnwindSafe(|| {
                for i in 0..n_tasks {
                    f(i);
                }
            })) {
                Ok(()) => Ok(()),
                Err(payload) => Err(ScopeFault {
                    message: payload_message(payload.as_ref()),
                }),
            };
        }

        let wide: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: only the lifetime is erased; layout of a fat reference and
        // a fat raw pointer is identical.  The protocol below guarantees no
        // dereference happens after this function returns.
        let body = BodyPtr(unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Sync),
                *const (dyn Fn(usize) + Sync + 'static),
            >(wide)
        });

        // Affinity mode: one contiguous stripe per participant (caller is
        // participant 0, helper `h` is `h + 1`).  Only worthwhile when
        // every participant gets at least a couple of tasks.
        let parts = helpers + 1;
        let stripes = if self.affinity_hints() && n_tasks >= parts * 2 {
            let per = n_tasks.div_ceil(parts);
            (0..parts)
                .map(|p| StripeCtl {
                    next: AtomicUsize::new((p * per).min(n_tasks)),
                    hi: ((p + 1) * per).min(n_tasks),
                })
                .collect()
        } else {
            Vec::new()
        };
        let ctl = Arc::new(ScopeCtl {
            next: AtomicUsize::new(0),
            stripes,
            borrowers: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
            panicked: AtomicBool::new(false),
            fault: Mutex::new(None),
            exit_mtx: Mutex::new(()),
            exit_cv: Condvar::new(),
        });
        for h in 0..helpers {
            let me = h + 1;
            let ctl = Arc::clone(&ctl);
            self.execute(move || {
                // Register as a borrower BEFORE touching the closure, and
                // re-check `closed` after registering: with SeqCst ordering
                // either the caller sees our registration and waits, or we
                // see `closed` and never dereference.
                if ctl.closed.load(Ordering::SeqCst) {
                    return;
                }
                ctl.borrowers.fetch_add(1, Ordering::SeqCst);
                let exit = BorrowerExit(Arc::clone(&ctl));
                if !ctl.closed.load(Ordering::SeqCst) {
                    // Catch panics so a failing task neither kills the
                    // worker nor strands the caller's borrower wait.
                    if let Err(payload) =
                        catch_unwind(AssertUnwindSafe(|| drive(body, &ctl, me, n_tasks)))
                    {
                        ctl.record_panic(payload.as_ref());
                    }
                }
                drop(exit);
            });
        }

        // The caller drives tasks itself — starvation-proof.  The guard
        // fences off late helpers and waits for in-flight ones on every
        // exit path, including unwinding out of a panicking body.
        let guard = CallerExit(Arc::clone(&ctl));
        let caller_result = catch_unwind(AssertUnwindSafe(|| drive(body, &ctl, 0, n_tasks)));
        drop(guard);
        if let Err(payload) = caller_result {
            ctl.record_panic(payload.as_ref());
        }
        if ctl.panicked.load(Ordering::SeqCst) {
            let msg = ctl
                .fault
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .take()
                .unwrap_or_else(|| "unknown panic".to_string());
            return Err(ScopeFault { message: msg });
        }
        Ok(())
    }

    /// Run `f` over items in parallel, preserving order of results.
    ///
    /// Built on `scoped_for`: results land in disjoint slots, so there is
    /// no shared results mutex (the old implementation serialized every
    /// completion on one lock) and no `'static` bound on `f` or the items.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let n = items.len();
        let mut slots: Vec<Option<T>> = items.into_iter().map(Some).collect();
        let mut out: Vec<Option<R>> = std::iter::repeat_with(|| None).take(n).collect();
        let slots_p = SendPtr::new(slots.as_mut_ptr());
        let out_p = SendPtr::new(out.as_mut_ptr());
        self.scoped_for(n, |i| {
            // SAFETY: each index is claimed by exactly one task, so slot
            // accesses are disjoint; both vectors outlive the region.
            unsafe {
                let item = (*slots_p.get().add(i)).take().expect("item taken once");
                *out_p.get().add(i) = Some(f(item));
            }
        });
        out.into_iter()
            .map(|o| o.expect("job completed"))
            .collect()
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break Some(job);
                }
                if *sh.shutdown.lock().unwrap() {
                    break None;
                }
                q = sh.available.wait(q).unwrap();
            }
        };
        match job {
            None => return,
            Some(job) => {
                // A panicking job must neither kill this worker (the pool
                // would silently shrink for the rest of its life) nor skip
                // the pending decrement below (`join` would wait forever).
                // Fleet replica workers run fault-injection chaos jobs
                // through `execute`, so this is load-bearing, not
                // defensive.  The payload is dropped: fire-and-forget jobs
                // have no return channel; jobs that need panic reporting
                // use `try_scoped_for`.
                let _ = catch_unwind(AssertUnwindSafe(job));
                if sh.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
                    let _q = sh.queue.lock().unwrap();
                    sh.idle.notify_all();
                }
            }
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn panicking_execute_jobs_kill_neither_workers_nor_join() {
        // Regression: `execute` jobs used to run unguarded, so one panic
        // unwound a worker thread (shrinking the pool) and stranded the
        // `pending` count (deadlocking `join`).  After the guard, every
        // panicking job still completes for accounting purposes and all
        // workers keep draining the queue.
        let pool = ThreadPool::new(2);
        let done = Arc::new(AtomicU64::new(0));
        for i in 0..20 {
            let d = Arc::clone(&done);
            pool.execute(move || {
                if i % 3 == 0 {
                    panic!("injected job panic");
                }
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join(); // would deadlock before the fix
        assert_eq!(done.load(Ordering::SeqCst), 13);
        // Both workers survived: 100 follow-up jobs all run.
        for _ in 0..100 {
            let d = Arc::clone(&done);
            pool.execute(move || {
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(done.load(Ordering::SeqCst), 113);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<u64>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn map_can_borrow_the_stack() {
        // The old map required 'static captures; the scoped version lets
        // the closure read local state without Arc.
        let pool = ThreadPool::new(4);
        let offset = 17u64;
        let out = pool.map((0..20).collect::<Vec<u64>>(), |x| x + offset);
        assert_eq!(out, (17..37).collect::<Vec<u64>>());
    }

    #[test]
    fn join_with_no_jobs_returns() {
        let pool = ThreadPool::new(2);
        pool.join();
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let out = pool.map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        pool.join();
        drop(pool);
    }

    #[test]
    fn scoped_for_runs_every_index_once() {
        for threads in [1, 2, 4, 8] {
            let pool = ThreadPool::new(threads);
            let n = 1000;
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.scoped_for(n, |i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::SeqCst) == 1),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn scoped_for_borrows_mutable_disjoint_slices() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0u64; 64];
        let base = SendPtr::new(data.as_mut_ptr());
        pool.scoped_for(64, |i| unsafe {
            *base.get().add(i) = (i * i) as u64;
        });
        assert!(data.iter().enumerate().all(|(i, &v)| v == (i * i) as u64));
    }

    #[test]
    fn scoped_for_zero_and_one_tasks() {
        let pool = ThreadPool::new(4);
        pool.scoped_for(0, |_| panic!("no tasks"));
        let ran = AtomicUsize::new(0);
        pool.scoped_for(1, |i| {
            assert_eq!(i, 0);
            ran.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn scoped_for_completes_when_all_workers_are_starved() {
        // Pin every worker on a gate, then run a scoped region: the caller
        // must drive all tasks itself and return without waiting for the
        // (still-blocked) helpers to ever start.
        let pool = ThreadPool::new(2);
        let gate = Arc::new(AtomicBool::new(false));
        for _ in 0..2 {
            let g = Arc::clone(&gate);
            pool.execute(move || {
                while !g.load(Ordering::SeqCst) {
                    std::thread::yield_now();
                }
            });
        }
        let done = AtomicUsize::new(0);
        pool.scoped_for(100, |_| {
            done.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(done.load(Ordering::SeqCst), 100);
        gate.store(true, Ordering::SeqCst); // release the pinned workers
        pool.join();
    }

    #[test]
    fn scoped_for_propagates_panics_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scoped_for(64, |i| {
                if i == 33 {
                    panic!("boom at {i}");
                }
            });
        }));
        assert!(r.is_err());
        // The pool is still functional afterwards (workers not killed,
        // join not stranded).
        let done = AtomicUsize::new(0);
        pool.scoped_for(16, |_| {
            done.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(done.load(Ordering::SeqCst), 16);
        pool.join();
    }

    #[test]
    fn try_scoped_for_ok_on_success() {
        let pool = ThreadPool::new(3);
        let done = AtomicUsize::new(0);
        assert!(pool
            .try_scoped_for(64, |_| {
                done.fetch_add(1, Ordering::SeqCst);
            })
            .is_ok());
        assert_eq!(done.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn try_scoped_for_reports_panics_without_unwinding() {
        for threads in [1, 4] {
            let pool = ThreadPool::new(threads);
            let err = pool
                .try_scoped_for(64, |i| {
                    if i == 21 {
                        panic!("chaos at {i}");
                    }
                })
                .expect_err("a task panicked");
            assert!(err.message.contains("chaos at 21"), "{}", err.message);
            // The region quiesced and the pool still works afterwards.
            let done = AtomicUsize::new(0);
            pool.scoped_for(16, |_| {
                done.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(done.load(Ordering::SeqCst), 16);
        }
    }

    #[test]
    fn try_scoped_for_serial_path_reports_panics() {
        // n_tasks == 1 takes the no-helper serial path; it must report,
        // not unwind, too.
        let pool = ThreadPool::new(4);
        let err = pool
            .try_scoped_for(1, |_| panic!("serial boom"))
            .expect_err("serial task panicked");
        assert!(err.message.contains("serial boom"));
    }

    #[test]
    fn affinity_scoped_for_runs_every_index_once() {
        // Striped claiming must preserve the exactly-once contract across
        // thread counts, uneven stripe sizes (odd n) and tiny regions that
        // fall back to the single counter.
        for threads in [1, 2, 4, 8] {
            let pool = ThreadPool::new(threads);
            pool.set_affinity_hints(true);
            assert!(pool.affinity_hints());
            for n in [1, 3, 7, 100, 1001] {
                let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                pool.scoped_for(n, |i| {
                    hits[i].fetch_add(1, Ordering::SeqCst);
                });
                assert!(
                    hits.iter().all(|h| h.load(Ordering::SeqCst) == 1),
                    "threads={threads} n={n}"
                );
            }
        }
    }

    #[test]
    fn affinity_scoped_for_completes_when_all_workers_are_starved() {
        // With every helper pinned, the caller must steal through all
        // stripes itself — ring-order stealing is load-bearing, not an
        // optimization.
        let pool = ThreadPool::new(2);
        pool.set_affinity_hints(true);
        let gate = Arc::new(AtomicBool::new(false));
        for _ in 0..2 {
            let g = Arc::clone(&gate);
            pool.execute(move || {
                while !g.load(Ordering::SeqCst) {
                    std::thread::yield_now();
                }
            });
        }
        let done = AtomicUsize::new(0);
        pool.scoped_for(100, |_| {
            done.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(done.load(Ordering::SeqCst), 100);
        gate.store(true, Ordering::SeqCst);
        pool.join();
    }

    #[test]
    fn affinity_hints_toggle() {
        let pool = ThreadPool::new(2);
        assert!(!pool.affinity_hints());
        pool.set_affinity_hints(true);
        assert!(pool.affinity_hints());
        pool.set_affinity_hints(false);
        assert!(!pool.affinity_hints());
    }

    #[test]
    fn nested_scoped_for_does_not_deadlock() {
        let pool = ThreadPool::new(2);
        let total = AtomicUsize::new(0);
        pool.scoped_for(4, |_| {
            pool.scoped_for(8, |_| {
                total.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(total.load(Ordering::SeqCst), 32);
    }
}
