//! Statistics substrate: online moments, percentiles, fixed-bucket latency
//! histograms — used by serving metrics and the bench harness.

/// Online mean/variance (Welford) with min/max tracking.
///
/// # Examples
///
/// ```
/// use shira::util::stats::Moments;
///
/// let mut m = Moments::new();
/// for x in [1.0, 2.0, 3.0] { m.push(x); }
/// assert_eq!(m.count(), 3);
/// assert!((m.mean() - 2.0).abs() < 1e-12);
/// assert_eq!((m.min(), m.max()), (1.0, 3.0));
/// ```
#[derive(Clone, Debug, Default)]
pub struct Moments {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Moments {
    /// Empty accumulator.
    pub fn new() -> Self {
        Moments {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold one observation in (O(1), numerically stable).
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Observations folded so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean (0 before the first push).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// Smallest observation (+inf before the first push).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation (−inf before the first push).
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact percentile over a stored sample (fine for bench-scale data).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Sample container with summary helpers (lazy sort for percentiles).
///
/// # Examples
///
/// ```
/// use shira::util::stats::Sample;
///
/// let mut s = Sample::new();
/// for x in [5.0, 1.0, 3.0] { s.push(x); }
/// assert_eq!(s.percentile(50.0), 3.0);
/// assert!((s.mean() - 3.0).abs() < 1e-12);
/// ```
#[derive(Clone, Debug, Default)]
pub struct Sample {
    xs: Vec<f64>,
    sorted: bool,
}

impl Sample {
    /// Empty sample.
    pub fn new() -> Self {
        Sample {
            xs: Vec::new(),
            sorted: true,
        }
    }

    /// Append one observation.
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    /// Observations collected.
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    /// True when no observations were collected.
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Exact interpolated percentile `p` in [0, 100] (sorts lazily).
    pub fn percentile(&mut self, p: f64) -> f64 {
        self.ensure_sorted();
        percentile(&self.xs, p)
    }

    /// Arithmetic mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    /// Sample standard deviation (0 with fewer than two observations).
    pub fn std(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m).powi(2)).sum::<f64>()
            / (self.xs.len() - 1) as f64)
            .sqrt()
    }

    /// Median absolute deviation — robust spread for outlier flagging.
    pub fn mad(&mut self) -> f64 {
        self.ensure_sorted();
        let med = percentile(&self.xs, 50.0);
        let mut devs: Vec<f64> = self.xs.iter().map(|x| (x - med).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile(&devs, 50.0)
    }

    /// The raw observations in insertion (or, after a percentile call,
    /// sorted) order.
    pub fn values(&self) -> &[f64] {
        &self.xs
    }
}

/// Log-scaled latency histogram (microsecond buckets, powers of ~2).
#[derive(Clone, Debug)]
pub struct LatencyHist {
    buckets: Vec<u64>, // bucket i covers [2^i, 2^(i+1)) us
    count: u64,
    sum_us: f64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    /// Empty histogram covering [1us, ~2^40us).
    pub fn new() -> Self {
        LatencyHist {
            buckets: vec![0; 40],
            count: 0,
            sum_us: 0.0,
        }
    }

    /// Record one latency in microseconds.
    pub fn record_us(&mut self, us: f64) {
        let b = if us < 1.0 {
            0
        } else {
            (us.log2().floor() as usize).min(self.buckets.len() - 1)
        };
        self.buckets[b] += 1;
        self.count += 1;
        self.sum_us += us;
    }

    /// Record one latency from a [`std::time::Duration`].
    pub fn record(&mut self, d: std::time::Duration) {
        self.record_us(d.as_secs_f64() * 1e6);
    }

    /// Latencies recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact mean latency in microseconds (tracked outside the buckets).
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us / self.count as f64
        }
    }

    /// Approximate percentile from bucket boundaries (upper bound).
    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * self.count as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return (1u64 << (i + 1)) as f64;
            }
        }
        (1u64 << self.buckets.len()) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments_basic() {
        let mut m = Moments::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            m.push(x);
        }
        assert_eq!(m.count(), 5);
        assert!((m.mean() - 3.0).abs() < 1e-12);
        assert!((m.std() - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(m.min(), 1.0);
        assert_eq!(m.max(), 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(percentile(&xs, 50.0), 25.0);
    }

    #[test]
    fn sample_stats() {
        let mut s = Sample::new();
        for x in [5.0, 1.0, 3.0] {
            s.push(x);
        }
        assert_eq!(s.percentile(50.0), 3.0);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.std() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let mut s = Sample::new();
        for x in [1.0, 1.1, 0.9, 1.0, 1.05, 100.0] {
            s.push(x);
        }
        assert!(s.mad() < 0.2, "mad={}", s.mad());
    }

    #[test]
    fn latency_hist_percentiles_monotone() {
        let mut h = LatencyHist::new();
        for i in 1..1000u64 {
            h.record_us(i as f64);
        }
        let p50 = h.percentile_us(50.0);
        let p99 = h.percentile_us(99.0);
        assert!(p50 <= p99);
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.count(), 999);
    }
}
