//! CLI argument parsing substrate (no clap in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! subcommands, with typed getters and a generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    present: Vec<String>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cli error: {}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse argv (without the program name).  `subcommands` lists legal
    /// first tokens; pass `&[]` to disable subcommand handling.
    pub fn parse(argv: &[String], subcommands: &[&str]) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') && subcommands.contains(&first.as_str()) {
                out.subcommand = Some(it.next().unwrap().clone());
            }
        }
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // "--" => rest is positional
                    out.positional.extend(it.by_ref().cloned());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                    out.present.push(k.to_string());
                } else {
                    // value-consuming iff the next token isn't another flag
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            out.flags
                                .insert(body.to_string(), it.next().unwrap().clone());
                        }
                        _ => {
                            out.flags.insert(body.to_string(), String::new());
                        }
                    }
                    out.present.push(body.to_string());
                }
            } else {
                out.positional.push(tok.clone());
            }
        }
        Ok(out)
    }

    pub fn from_env(subcommands: &[&str]) -> Result<Args, CliError> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv, subcommands)
    }

    pub fn has(&self, key: &str) -> bool {
        self.present.iter().any(|k| k == key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str()).filter(|s| !s.is_empty())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected integer, got '{v}'"))),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected integer, got '{v}'"))),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected number, got '{v}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = Args::parse(&argv("train --kind shira --steps 100 --verbose"),
                            &["train", "serve"]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("kind"), Some("shira"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(&argv("--lr=0.002 --out=path/x"), &[]).unwrap();
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.002);
        assert_eq!(a.get("out"), Some("path/x"));
    }

    #[test]
    fn positional_and_double_dash() {
        let a = Args::parse(&argv("run a b -- --not-a-flag"), &["run"]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["a", "b", "--not-a-flag"]);
    }

    #[test]
    fn flag_before_flag_is_boolean() {
        let a = Args::parse(&argv("--fast --steps 5"), &[]).unwrap();
        assert!(a.has("fast"));
        assert_eq!(a.get("fast"), None);
        assert_eq!(a.get_usize("steps", 0).unwrap(), 5);
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(&argv("--steps nope"), &[]).unwrap();
        assert!(a.get_usize("steps", 0).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&[], &[]).unwrap();
        assert_eq!(a.get_or("mode", "serve"), "serve");
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
    }
}
