//! Bench harness substrate (criterion is not in the offline vendor set).
//!
//! Criterion-like protocol: warmup, calibrated iteration count, N timed
//! samples, mean ± std with MAD-based outlier flagging.  Benches register
//! with `Bencher` and emit both a human table and a machine-readable JSON
//! lines file under `target/bench-results/`.
//!
//! ## Regression harness (DESIGN.md §6)
//!
//! Benches additionally emit a `BENCH_<name>.json` baseline document with
//! mean/p50/p99 per stage.  Passing `--check` to a bench compares the
//! fresh run against the committed baseline (`rust/BENCH_<name>.json`) and
//! exits nonzero on regression beyond a tolerance; `--save-baseline`
//! rewrites the committed file from the current run.

use std::path::Path;
use std::time::{Duration, Instant};

use super::json::{self, Json};
use super::stats::Sample;

/// One benchmark's measured statistics.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Full name, `group/bench`.
    pub name: String,
    /// Mean time per operation, nanoseconds.
    pub mean_ns: f64,
    /// Standard deviation across samples, nanoseconds.
    pub std_ns: f64,
    /// Median (p50) time per operation, nanoseconds.
    pub median_ns: f64,
    /// 99th-percentile time per operation, nanoseconds.
    pub p99_ns: f64,
    /// Timed samples taken.
    pub samples: usize,
    /// Iterations batched into each sample.
    pub iters_per_sample: u64,
    /// Samples farther than 5 MADs from the median.
    pub outliers: usize,
}

impl BenchResult {
    /// Mean in microseconds.
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    /// Mean in milliseconds.
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Criterion-like benchmark driver (module docs) collecting
/// [`BenchResult`]s.
pub struct Bencher {
    /// Warmup duration before calibration.
    pub warmup: Duration,
    /// Number of timed samples per bench.
    pub measure_samples: usize,
    /// Target wall time per sample (sets the per-sample iteration count).
    pub target_sample_time: Duration,
    results: Vec<BenchResult>,
    group: String,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    /// Bencher with the default (or, under `SHIRA_BENCH_FAST=1`, the
    /// shrunk CI smoke) protocol.
    pub fn new() -> Self {
        // SHIRA_BENCH_FAST=1 shrinks the protocol for CI smoke runs.
        let fast = std::env::var("SHIRA_BENCH_FAST").is_ok();
        Bencher {
            warmup: if fast {
                Duration::from_millis(20)
            } else {
                Duration::from_millis(300)
            },
            measure_samples: if fast { 5 } else { 15 },
            target_sample_time: if fast {
                Duration::from_millis(20)
            } else {
                Duration::from_millis(100)
            },
            results: Vec::new(),
            group: String::new(),
        }
    }

    /// Start a named group; subsequent benches are reported as
    /// `group/name`.
    pub fn group(&mut self, name: &str) {
        self.group = name.to_string();
        println!("\n== {name} ==");
    }

    /// Benchmark `f`, which performs ONE logical operation per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchResult {
        // Warmup + calibration: how many iters fit in target_sample_time?
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup {
            f();
            warm_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / warm_iters.max(1) as f64;
        let iters = ((self.target_sample_time.as_secs_f64() / per_iter).ceil()
            as u64)
            .max(1);

        let mut sample = Sample::new();
        for _ in 0..self.measure_samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
            sample.push(ns);
        }
        let median = sample.percentile(50.0);
        let p99 = sample.percentile(99.0);
        let mad = sample.mad().max(1.0);
        let outliers = sample
            .values()
            .iter()
            .filter(|&&x| (x - median).abs() > 5.0 * mad)
            .count();
        let res = BenchResult {
            name: if self.group.is_empty() {
                name.to_string()
            } else {
                format!("{}/{}", self.group, name)
            },
            mean_ns: sample.mean(),
            std_ns: sample.std(),
            median_ns: median,
            p99_ns: p99,
            samples: self.measure_samples,
            iters_per_sample: iters,
            outliers,
        };
        println!(
            "  {:48} {:>12} ± {:>10}  (median {:>12}, {} iters/sample{})",
            res.name,
            fmt_ns(res.mean_ns),
            fmt_ns(res.std_ns),
            fmt_ns(res.median_ns),
            iters,
            if outliers > 0 {
                format!(", {outliers} outliers")
            } else {
                String::new()
            }
        );
        self.results.push(res.clone());
        res
    }

    /// Write results as JSON-lines for downstream tooling / EXPERIMENTS.md.
    pub fn write_results(&self, file_stem: &str) {
        let dir = std::path::Path::new("target/bench-results");
        let _ = std::fs::create_dir_all(dir);
        let mut out = String::new();
        for r in &self.results {
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"mean_ns\":{:.1},\"std_ns\":{:.1},\"median_ns\":{:.1},\"p99_ns\":{:.1},\"samples\":{},\"iters\":{}}}\n",
                r.name, r.mean_ns, r.std_ns, r.median_ns, r.p99_ns, r.samples,
                r.iters_per_sample
            ));
        }
        let path = dir.join(format!("{file_stem}.jsonl"));
        if std::fs::write(&path, out).is_ok() {
            println!("\nresults -> {}", path.display());
        }
    }

    /// All results measured so far, in run order.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

// ---------------------------------------------------------------------------
// Bench-regression harness: BENCH_*.json baselines + --check mode
// ---------------------------------------------------------------------------

/// One stage's record in a baseline document.
#[derive(Clone, Debug, PartialEq)]
pub struct BaselineEntry {
    /// Stage name (matches the bench's `group/name`).
    pub name: String,
    /// Mean nanoseconds per operation.
    pub mean_ns: f64,
    /// Median nanoseconds per operation (the value `--check` gates on).
    pub p50_ns: f64,
    /// 99th-percentile nanoseconds per operation.
    pub p99_ns: f64,
}

/// Serialize results as a `BENCH_*.json` baseline document.
///
/// # Examples
///
/// ```
/// use shira::util::benchlib::{baseline_json, BaselineEntry};
///
/// let entries = vec![BaselineEntry {
///     name: "fig5/dim512/shira_scatter".into(),
///     mean_ns: 1200.0,
///     p50_ns: 1100.0,
///     p99_ns: 2000.0,
/// }];
/// let doc = baseline_json("bench_switch", "example", &entries);
/// assert!(doc.contains("\"bench\": \"bench_switch\""));
/// assert!(doc.contains("shira_scatter"));
/// ```
pub fn baseline_json(bench: &str, note: &str, entries: &[BaselineEntry]) -> String {
    let arr = entries
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("name", Json::str(&e.name)),
                ("mean_ns", Json::num((e.mean_ns * 10.0).round() / 10.0)),
                ("p50_ns", Json::num((e.p50_ns * 10.0).round() / 10.0)),
                ("p99_ns", Json::num((e.p99_ns * 10.0).round() / 10.0)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::str(bench)),
        ("schema", Json::num(1.0)),
        ("note", Json::str(note)),
        ("entries", Json::Arr(arr)),
    ])
    .to_string_pretty()
        + "\n"
}

/// Project [`BenchResult`]s onto the baseline-entry schema.
pub fn results_to_entries(results: &[BenchResult]) -> Vec<BaselineEntry> {
    results
        .iter()
        .map(|r| BaselineEntry {
            name: r.name.clone(),
            mean_ns: r.mean_ns,
            p50_ns: r.median_ns,
            p99_ns: r.p99_ns,
        })
        .collect()
}

/// Write a baseline document; returns false (and warns) on IO failure.
pub fn write_baseline(path: &Path, bench: &str, note: &str, entries: &[BaselineEntry]) -> bool {
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    match std::fs::write(path, baseline_json(bench, note, entries)) {
        Ok(()) => {
            println!("baseline -> {}", path.display());
            true
        }
        Err(e) => {
            eprintln!("warning: could not write baseline {}: {e}", path.display());
            false
        }
    }
}

/// Parse a baseline document written by [`write_baseline`].
pub fn load_baseline(path: &Path) -> Result<Vec<BaselineEntry>, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("reading {}: {e}", path.display()))?;
    let j = json::parse(&text).map_err(|e| format!("parsing {}: {e}", path.display()))?;
    let entries = j
        .get("entries")
        .and_then(|e| e.as_arr())
        .ok_or_else(|| format!("{}: missing entries array", path.display()))?;
    entries
        .iter()
        .map(|e| {
            let name = e
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or("entry missing name")?
                .to_string();
            let num = |k: &str| {
                e.get(k)
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| format!("entry {name} missing {k}"))
            };
            Ok(BaselineEntry {
                mean_ns: num("mean_ns")?,
                p50_ns: num("p50_ns")?,
                p99_ns: num("p99_ns")?,
                name,
            })
        })
        .collect()
}

/// Outcome of comparing a fresh run against a committed baseline.
#[derive(Clone, Debug, Default)]
pub struct RegressionReport {
    /// Human-readable "name: current vs baseline (+x%)" lines.
    pub regressions: Vec<String>,
    /// Stages present in both the run and the baseline.
    pub compared: usize,
    /// Stages present in the run but absent from the baseline (or vice
    /// versa) — reported, not failed, so adding a bench stage is not a
    /// regression.
    pub unmatched: usize,
}

impl RegressionReport {
    /// True when no stage regressed beyond tolerance.
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
    }
}

/// Compare current p50s against the baseline's; a stage regresses when it
/// is slower by more than `tolerance` (fractional, e.g. 0.5 = +50%).
/// Medians are compared rather than means so one outlier sample cannot
/// fail CI.
pub fn check_regression(
    current: &[BaselineEntry],
    baseline: &[BaselineEntry],
    tolerance: f64,
) -> RegressionReport {
    let mut report = RegressionReport::default();
    for cur in current {
        match baseline.iter().find(|b| b.name == cur.name) {
            None => report.unmatched += 1,
            Some(base) => {
                report.compared += 1;
                let limit = base.p50_ns * (1.0 + tolerance);
                if cur.p50_ns > limit && base.p50_ns > 0.0 {
                    report.regressions.push(format!(
                        "{}: p50 {} vs baseline {} (+{:.0}%, tolerance {:.0}%)",
                        cur.name,
                        fmt_ns(cur.p50_ns),
                        fmt_ns(base.p50_ns),
                        100.0 * (cur.p50_ns / base.p50_ns - 1.0),
                        100.0 * tolerance
                    ));
                }
            }
        }
    }
    report.unmatched += baseline
        .iter()
        .filter(|b| !current.iter().any(|c| c.name == b.name))
        .count();
    report
}

/// Shared CLI plumbing for bench mains: handles `--check`, `--tolerance`,
/// `--save-baseline`, `--baseline-dir <dir>` and `--require-entries`
/// against the baseline `BENCH_<stem>.json` (committed under the crate
/// root by default; `--baseline-dir` points both save and check at
/// another directory, which is how CI exercises the full compare path
/// without touching the committed placeholders).  Always also writes the
/// fresh document under `target/bench-results/`.  Returns `false` when
/// `--check` found a regression (caller should exit nonzero).
///
/// `--require-entries` hardens `--check`: an empty run, an unusable or
/// missing baseline, or zero compared stages — all of which plain
/// `--check` treats as a pass so committed placeholders stay green —
/// become failures.  CI pairs it with a `--save-baseline --baseline-dir`
/// run of the same bench so the gate is exercised non-trivially.
pub fn finish_bench(stem: &str, entries: &[BaselineEntry]) -> bool {
    let args: Vec<String> = std::env::args().collect();
    finish_bench_with(stem, entries, &args)
}

/// Testable core of [`finish_bench`]: identical flag handling with the
/// argument list injected instead of read from the process environment.
pub fn finish_bench_with(stem: &str, entries: &[BaselineEntry], args: &[String]) -> bool {
    let tolerance = args
        .iter()
        .position(|a| a == "--tolerance")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(0.5);
    let baseline_path = match args
        .iter()
        .position(|a| a == "--baseline-dir")
        .and_then(|i| args.get(i + 1))
    {
        Some(dir) => Path::new(dir).join(format!("BENCH_{stem}.json")),
        None => Path::new(env!("CARGO_MANIFEST_DIR")).join(format!("BENCH_{stem}.json")),
    };
    let require = args.iter().any(|a| a == "--require-entries");
    let fresh = Path::new("target/bench-results").join(format!("BENCH_{stem}.json"));
    write_baseline(
        &fresh,
        &format!("bench_{stem}"),
        "fresh run (not a committed baseline)",
        entries,
    );
    if args.iter().any(|a| a == "--save-baseline") {
        write_baseline(
            &baseline_path,
            &format!("bench_{stem}"),
            "committed baseline; regenerate with --save-baseline",
            entries,
        );
    }
    if args.iter().any(|a| a == "--check") {
        if require && entries.is_empty() {
            eprintln!("--check --require-entries: bench produced no entries");
            return false;
        }
        match load_baseline(&baseline_path) {
            Err(e) if require => {
                eprintln!("--check --require-entries: no usable baseline ({e})");
                false
            }
            Err(e) => {
                eprintln!("--check: no usable baseline ({e}); treating as pass");
                true
            }
            Ok(base) => {
                let report = check_regression(entries, &base, tolerance);
                if require && report.compared == 0 {
                    eprintln!(
                        "--check --require-entries: no stages matched {}",
                        baseline_path.display()
                    );
                    false
                } else if report.passed() {
                    println!(
                        "--check: OK ({} stages within {:.0}% of {})",
                        report.compared,
                        tolerance * 100.0,
                        baseline_path.display()
                    );
                    true
                } else {
                    eprintln!("--check: REGRESSION vs {}", baseline_path.display());
                    for r in &report.regressions {
                        eprintln!("  {r}");
                    }
                    false
                }
            }
        }
    } else {
        true
    }
}

/// Format a nanosecond count with a human-friendly unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_numbers() {
        std::env::set_var("SHIRA_BENCH_FAST", "1");
        let mut b = Bencher::new();
        let mut acc = 0u64;
        let r = b.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.mean_ns < 1e6); // an add is < 1ms
        assert_eq!(r.samples, 5);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5e3).contains("us"));
        assert!(fmt_ns(5e6).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }

    fn entry(name: &str, p50: f64) -> BaselineEntry {
        // Values chosen to be exact at the 0.1 ns precision the JSON
        // writer rounds to, so the roundtrip compares equal.
        BaselineEntry {
            name: name.to_string(),
            mean_ns: p50 + 0.5,
            p50_ns: p50,
            p99_ns: p50 * 2.0,
        }
    }

    #[test]
    fn baseline_roundtrips_through_json() {
        let entries = vec![entry("fig5/dim4096/shira_scatter", 1234.5), entry("x", 7.0)];
        let text = baseline_json("bench_switch", "test", &entries);
        let dir = std::env::temp_dir().join("shira-benchlib-test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("BENCH_test.json");
        std::fs::write(&path, &text).unwrap();
        let loaded = load_baseline(&path).unwrap();
        assert_eq!(loaded, entries);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn regression_check_flags_only_slowdowns() {
        let base = vec![entry("a", 100.0), entry("b", 100.0), entry("gone", 5.0)];
        let cur = vec![
            entry("a", 120.0), // +20% — within 50% tolerance
            entry("b", 300.0), // +200% — regression
            entry("new", 9.0), // unmatched, not a failure
        ];
        let rep = check_regression(&cur, &base, 0.5);
        assert_eq!(rep.compared, 2);
        assert_eq!(rep.regressions.len(), 1);
        assert!(rep.regressions[0].starts_with("b:"));
        assert_eq!(rep.unmatched, 2); // "new" and "gone"
        assert!(!rep.passed());
        assert!(check_regression(&cur, &base, 3.0).passed());
    }

    #[test]
    fn missing_baseline_is_an_error() {
        assert!(load_baseline(std::path::Path::new("/nonexistent/BENCH_x.json")).is_err());
    }

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn save_then_check_with_baseline_dir_roundtrips() {
        // The CI flow: --save-baseline into a temp dir, then --check
        // --require-entries against it — must pass non-trivially.
        let dir = std::env::temp_dir().join("shira-benchlib-savecheck");
        let _ = std::fs::create_dir_all(&dir);
        let d = dir.to_string_lossy().to_string();
        let entries = vec![entry("k/a", 100.0), entry("k/b", 50.0)];
        assert!(finish_bench_with(
            "savecheck",
            &entries,
            &argv(&["bench", "--save-baseline", "--baseline-dir", &d]),
        ));
        assert!(finish_bench_with(
            "savecheck",
            &entries,
            &argv(&["bench", "--check", "--require-entries", "--baseline-dir", &d]),
        ));
        // A real regression against the saved baseline still fails.
        let slow = vec![entry("k/a", 1000.0), entry("k/b", 50.0)];
        assert!(!finish_bench_with(
            "savecheck",
            &slow,
            &argv(&["bench", "--check", "--require-entries", "--baseline-dir", &d]),
        ));
        let _ = std::fs::remove_file(dir.join("BENCH_savecheck.json"));
    }

    #[test]
    fn require_entries_rejects_trivial_passes() {
        let dir = std::env::temp_dir().join("shira-benchlib-require");
        let _ = std::fs::create_dir_all(&dir);
        let _ = std::fs::remove_file(dir.join("BENCH_req.json"));
        let d = dir.to_string_lossy().to_string();
        // No entries at all.
        assert!(!finish_bench_with(
            "req",
            &[],
            &argv(&["bench", "--check", "--require-entries", "--baseline-dir", &d]),
        ));
        // No baseline file to compare against.
        let entries = vec![entry("k/a", 100.0)];
        assert!(!finish_bench_with(
            "req",
            &entries,
            &argv(&["bench", "--check", "--require-entries", "--baseline-dir", &d]),
        ));
        // Baseline exists but shares no stage names: compared == 0.
        std::fs::write(
            dir.join("BENCH_req.json"),
            baseline_json("bench_req", "t", &[entry("other/name", 5.0)]),
        )
        .unwrap();
        assert!(!finish_bench_with(
            "req",
            &entries,
            &argv(&["bench", "--check", "--require-entries", "--baseline-dir", &d]),
        ));
        // Plain --check still treats all three as a pass (placeholder
        // behaviour, unchanged).
        assert!(finish_bench_with(
            "req",
            &entries,
            &argv(&["bench", "--check", "--baseline-dir", &d]),
        ));
        let _ = std::fs::remove_file(dir.join("BENCH_req.json"));
    }
}
