//! Bench harness substrate (criterion is not in the offline vendor set).
//!
//! Criterion-like protocol: warmup, calibrated iteration count, N timed
//! samples, mean ± std with MAD-based outlier flagging.  Benches register
//! with `Bencher` and emit both a human table and a machine-readable JSON
//! lines file under `target/bench-results/`.

use std::time::{Duration, Instant};

use super::stats::Sample;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub median_ns: f64,
    pub samples: usize,
    pub iters_per_sample: u64,
    pub outliers: usize,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

pub struct Bencher {
    pub warmup: Duration,
    pub measure_samples: usize,
    pub target_sample_time: Duration,
    results: Vec<BenchResult>,
    group: String,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // SHIRA_BENCH_FAST=1 shrinks the protocol for CI smoke runs.
        let fast = std::env::var("SHIRA_BENCH_FAST").is_ok();
        Bencher {
            warmup: if fast {
                Duration::from_millis(20)
            } else {
                Duration::from_millis(300)
            },
            measure_samples: if fast { 5 } else { 15 },
            target_sample_time: if fast {
                Duration::from_millis(20)
            } else {
                Duration::from_millis(100)
            },
            results: Vec::new(),
            group: String::new(),
        }
    }

    pub fn group(&mut self, name: &str) {
        self.group = name.to_string();
        println!("\n== {name} ==");
    }

    /// Benchmark `f`, which performs ONE logical operation per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchResult {
        // Warmup + calibration: how many iters fit in target_sample_time?
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup {
            f();
            warm_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / warm_iters.max(1) as f64;
        let iters = ((self.target_sample_time.as_secs_f64() / per_iter).ceil()
            as u64)
            .max(1);

        let mut sample = Sample::new();
        for _ in 0..self.measure_samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
            sample.push(ns);
        }
        let median = sample.percentile(50.0);
        let mad = sample.mad().max(1.0);
        let outliers = sample
            .values()
            .iter()
            .filter(|&&x| (x - median).abs() > 5.0 * mad)
            .count();
        let res = BenchResult {
            name: if self.group.is_empty() {
                name.to_string()
            } else {
                format!("{}/{}", self.group, name)
            },
            mean_ns: sample.mean(),
            std_ns: sample.std(),
            median_ns: median,
            samples: self.measure_samples,
            iters_per_sample: iters,
            outliers,
        };
        println!(
            "  {:48} {:>12} ± {:>10}  (median {:>12}, {} iters/sample{})",
            res.name,
            fmt_ns(res.mean_ns),
            fmt_ns(res.std_ns),
            fmt_ns(res.median_ns),
            iters,
            if outliers > 0 {
                format!(", {outliers} outliers")
            } else {
                String::new()
            }
        );
        self.results.push(res.clone());
        res
    }

    /// Write results as JSON-lines for downstream tooling / EXPERIMENTS.md.
    pub fn write_results(&self, file_stem: &str) {
        let dir = std::path::Path::new("target/bench-results");
        let _ = std::fs::create_dir_all(dir);
        let mut out = String::new();
        for r in &self.results {
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"mean_ns\":{:.1},\"std_ns\":{:.1},\"median_ns\":{:.1},\"samples\":{},\"iters\":{}}}\n",
                r.name, r.mean_ns, r.std_ns, r.median_ns, r.samples,
                r.iters_per_sample
            ));
        }
        let path = dir.join(format!("{file_stem}.jsonl"));
        if std::fs::write(&path, out).is_ok() {
            println!("\nresults -> {}", path.display());
        }
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_numbers() {
        std::env::set_var("SHIRA_BENCH_FAST", "1");
        let mut b = Bencher::new();
        let mut acc = 0u64;
        let r = b.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.mean_ns < 1e6); // an add is < 1ms
        assert_eq!(r.samples, 5);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5e3).contains("us"));
        assert!(fmt_ns(5e6).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }
}
