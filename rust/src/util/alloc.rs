//! Byte accounting for peak-memory measurements (paper Table 6).
//!
//! Two complementary trackers:
//!
//! * `CountingAllocator` — a `GlobalAlloc` wrapper counting live + peak
//!   rust-heap bytes.  Installed by the bench binaries (`#[global_allocator]`).
//! * `MemLedger` — logical accounting of model/optimizer/adapter buffers
//!   (including XLA-side literals, which the rust allocator cannot see).
//!   This is the quantity the paper reasons about: SHiRA's optimizer state
//!   is O(k), LoRA's O(K_lora), DoRA's O(K_dora), full-FT's O(N).

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

pub struct CountingAllocator;

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let live = LIVE.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK.fetch_max(live, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
    }
}

impl CountingAllocator {
    pub fn live_bytes() -> usize {
        LIVE.load(Ordering::Relaxed)
    }

    pub fn peak_bytes() -> usize {
        PEAK.load(Ordering::Relaxed)
    }

    /// Reset the peak to the current live value (scoped measurements).
    pub fn reset_peak() {
        PEAK.store(LIVE.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

/// Logical buffer ledger, keyed by category ("params", "optimizer",
/// "adapter", "activations", ...).
#[derive(Debug, Default)]
pub struct MemLedger {
    inner: Mutex<LedgerInner>,
}

#[derive(Debug, Default)]
struct LedgerInner {
    live: BTreeMap<String, i64>,
    peak_total: i64,
}

impl MemLedger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn alloc(&self, category: &str, bytes: usize) {
        let mut g = self.inner.lock().unwrap();
        *g.live.entry(category.to_string()).or_insert(0) += bytes as i64;
        let total: i64 = g.live.values().sum();
        g.peak_total = g.peak_total.max(total);
    }

    pub fn free(&self, category: &str, bytes: usize) {
        let mut g = self.inner.lock().unwrap();
        let e = g.live.entry(category.to_string()).or_insert(0);
        *e -= bytes as i64;
        debug_assert!(*e >= 0, "ledger underflow in {category}");
    }

    pub fn live(&self, category: &str) -> usize {
        let g = self.inner.lock().unwrap();
        (*g.live.get(category).unwrap_or(&0)).max(0) as usize
    }

    pub fn live_total(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.live.values().sum::<i64>().max(0) as usize
    }

    pub fn peak_total(&self) -> usize {
        self.inner.lock().unwrap().peak_total.max(0) as usize
    }

    pub fn breakdown(&self) -> Vec<(String, usize)> {
        let g = self.inner.lock().unwrap();
        g.live
            .iter()
            .map(|(k, &v)| (k.clone(), v.max(0) as usize))
            .collect()
    }
}

pub fn fmt_bytes(b: usize) -> String {
    if b < 1024 {
        format!("{b} B")
    } else if b < 1024 * 1024 {
        format!("{:.1} KiB", b as f64 / 1024.0)
    } else if b < 1024 * 1024 * 1024 {
        format!("{:.2} MiB", b as f64 / (1024.0 * 1024.0))
    } else {
        format!("{:.2} GiB", b as f64 / (1024.0 * 1024.0 * 1024.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_tracks_peak() {
        let l = MemLedger::new();
        l.alloc("params", 1000);
        l.alloc("optimizer", 2000);
        assert_eq!(l.live_total(), 3000);
        assert_eq!(l.peak_total(), 3000);
        l.free("optimizer", 2000);
        l.alloc("adapter", 500);
        assert_eq!(l.live_total(), 1500);
        assert_eq!(l.peak_total(), 3000); // peak survives frees
        assert_eq!(l.live("params"), 1000);
    }

    #[test]
    fn breakdown_lists_categories() {
        let l = MemLedger::new();
        l.alloc("a", 1);
        l.alloc("b", 2);
        let bd = l.breakdown();
        assert_eq!(bd.len(), 2);
        assert_eq!(bd[0], ("a".to_string(), 1));
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(12), "12 B");
        assert!(fmt_bytes(2048).contains("KiB"));
        assert!(fmt_bytes(3 * 1024 * 1024).contains("MiB"));
        assert!(fmt_bytes(5 * 1024 * 1024 * 1024).contains("GiB"));
    }
}
