//! Property-testing substrate (proptest is not in the offline vendor set).
//!
//! A small QuickCheck-style harness: generators over an `Rng`, a fixed
//! case budget, and greedy input shrinking for failures.  Used to check the
//! coordinator/sparse-algebra invariants in DESIGN.md §7.

use super::rng::Rng;

pub const DEFAULT_CASES: usize = 64;

/// A generator is any `Fn(&mut Rng) -> T`.
pub trait Gen<T>: Fn(&mut Rng) -> T {}
impl<T, F: Fn(&mut Rng) -> T> Gen<T> for F {}

/// Run `prop` over `cases` random inputs; panic with the (shrunk, when a
/// shrinker is provided) counterexample on failure.
pub fn forall<T, G, P>(seed: u64, cases: usize, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> bool,
{
    forall_shrink(seed, cases, gen, |_| Vec::new(), prop)
}

/// `forall` with a shrinker: on failure, repeatedly replace the failing
/// input with the first smaller failing candidate until a fixpoint.
pub fn forall_shrink<T, G, S, P>(seed: u64, cases: usize, gen: G, shrink: S, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Fn(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> bool,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if prop(&input) {
            continue;
        }
        // shrink
        let mut worst = input;
        let mut budget = 200;
        'outer: while budget > 0 {
            for cand in shrink(&worst) {
                budget -= 1;
                if !prop(&cand) {
                    worst = cand;
                    continue 'outer;
                }
                if budget == 0 {
                    break;
                }
            }
            break;
        }
        panic!("property failed at case {case} (seed {seed}):\n  input = {worst:?}");
    }
}

// -- common generators ------------------------------------------------------

pub fn usize_in(lo: usize, hi: usize) -> impl Fn(&mut Rng) -> usize {
    move |r| lo + r.below(hi - lo + 1)
}

pub fn f32_in(lo: f32, hi: f32) -> impl Fn(&mut Rng) -> f32 {
    move |r| lo + r.uniform_f32() * (hi - lo)
}

pub fn vec_of<T>(
    len: impl Fn(&mut Rng) -> usize,
    item: impl Fn(&mut Rng) -> T,
) -> impl Fn(&mut Rng) -> Vec<T> {
    move |r| {
        let n = len(r);
        (0..n).map(|_| item(r)).collect()
    }
}

/// Shrinker for vectors: drop halves, then single elements.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    let n = v.len();
    if n == 0 {
        return out;
    }
    out.push(v[..n / 2].to_vec());
    out.push(v[n / 2..].to_vec());
    if n <= 8 {
        for i in 0..n {
            let mut w = v.to_vec();
            w.remove(i);
            out.push(w);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(1, 100, |r| r.below(1000), |&x| x < 1000);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(2, 100, |r| r.below(1000), |&x| x < 500);
    }

    #[test]
    fn shrinking_finds_small_case() {
        // Property: sum < 100. Shrinker should reduce the vector.
        let res = std::panic::catch_unwind(|| {
            forall_shrink(
                3,
                200,
                vec_of(usize_in(0, 20), usize_in(0, 50)),
                |v| shrink_vec(v),
                |v: &Vec<usize>| v.iter().sum::<usize>() < 100,
            );
        });
        assert!(res.is_err());
    }

    #[test]
    fn generators_in_range() {
        let mut r = Rng::new(4);
        for _ in 0..100 {
            let x = usize_in(5, 10)(&mut r);
            assert!((5..=10).contains(&x));
            let f = f32_in(-1.0, 1.0)(&mut r);
            assert!((-1.0..=1.0).contains(&f));
        }
    }
}
