//! Deterministic PRNG substrate: xoshiro256++ with named sub-streams.
//!
//! Every stochastic choice in the framework (data generation, weight init,
//! Rand masks, trace arrival jitter) flows from a named stream derived from
//! a root seed, so every experiment is exactly reproducible from its config.
//! No external crates (the offline vendor set has no `rand`), no wall-clock.

/// SplitMix64 — used to expand seeds into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// FNV-1a over a label, for deriving independent named streams.
fn fnv1a(label: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in label.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// xoshiro256++ generator with named sub-streams.
///
/// # Examples
///
/// ```
/// use shira::util::rng::Rng;
///
/// let mut a = Rng::new(42);
/// let mut b = Rng::new(42);
/// assert_eq!(a.next_u64(), b.next_u64()); // fully deterministic
/// let mut masks = Rng::new(42).stream("mask/rand");
/// assert!(masks.below(10) < 10);
/// ```
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller sample.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Generator seeded from `seed` via SplitMix64 state expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        // Avoid the all-zero state (probability ~0 but cheap to guard).
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream for `label` (e.g. "mask/rand/l0.wq").
    pub fn stream(&self, label: &str) -> Rng {
        Rng::new(self.s[0] ^ fnv1a(label).rotate_left(17) ^ self.s[2])
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3]))
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Next raw 32-bit output (upper half of [`Self::next_u64`]).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire reduction).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Gaussian f32 with the given mean and standard deviation.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill with gaussian values.
    pub fn fill_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(mean, std);
        }
    }

    /// k distinct indices from [0, n), sorted ascending.
    ///
    /// Uses Floyd's algorithm for k << n (the SHiRA regime) and a partial
    /// Fisher-Yates otherwise.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<u32> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut picked: Vec<u32>;
        if k * 20 <= n {
            // Floyd's: O(k) expected, set-backed.
            let mut set = std::collections::HashSet::with_capacity(k * 2);
            for j in (n - k)..n {
                let t = self.below(j + 1);
                let chosen = if set.contains(&(t as u32)) { j as u32 } else { t as u32 };
                set.insert(chosen);
            }
            picked = set.into_iter().collect();
        } else {
            let mut pool: Vec<u32> = (0..n as u32).collect();
            for i in 0..k {
                let j = i + self.below(n - i);
                pool.swap(i, j);
            }
            picked = pool[..k].to_vec();
        }
        picked.sort_unstable();
        picked
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn streams_are_independent_and_stable() {
        let root = Rng::new(7);
        let mut s1 = root.stream("mask/rand");
        let mut s1b = root.stream("mask/rand");
        let mut s2 = root.stream("data/tasks");
        assert_eq!(s1.next_u64(), s1b.next_u64());
        assert_ne!(s1.next_u64(), s2.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(8);
        for (n, k) in [(100, 5), (100, 90), (16384, 164), (1, 1), (50, 50)] {
            let idx = r.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            assert!(idx.windows(2).all(|w| w[0] < w[1]), "{n} {k}");
            assert!(idx.iter().all(|&i| (i as usize) < n));
        }
    }

    #[test]
    fn sample_indices_uniformity() {
        // Each index should appear with roughly equal frequency.
        let mut r = Rng::new(9);
        let mut counts = [0u32; 20];
        for _ in 0..2000 {
            for i in r.sample_indices(20, 5) {
                counts[i as usize] += 1;
            }
        }
        let expect = 2000.0 * 5.0 / 20.0;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expect).abs() < expect * 0.2,
                "index {i}: {c} vs {expect}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
