//! Substrate layer: everything the framework needs that the offline vendor
//! set does not provide (see DESIGN.md §5, S19/S21).

// benchlib, threadpool, rng, stats and json (the substrate the serving
// core leans on) are fully documented and doc-tested; alloc/cli/log/
// proptest remain for a follow-up docs pass.
#[allow(missing_docs)]
pub mod alloc;
pub mod benchlib;
#[allow(missing_docs)]
pub mod cli;
pub mod json;
#[allow(missing_docs)]
pub mod log;
#[allow(missing_docs)]
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;
