//! Substrate layer: everything the framework needs that the offline vendor
//! set does not provide (see DESIGN.md §5, S19/S21).

// benchlib (the public bench/regression harness) is fully documented and
// doc-tested; the remaining substrate modules are tracked for a follow-up
// docs pass.
#[allow(missing_docs)]
pub mod alloc;
pub mod benchlib;
#[allow(missing_docs)]
pub mod cli;
#[allow(missing_docs)]
pub mod json;
#[allow(missing_docs)]
pub mod log;
#[allow(missing_docs)]
pub mod proptest;
#[allow(missing_docs)]
pub mod rng;
#[allow(missing_docs)]
pub mod stats;
#[allow(missing_docs)]
pub mod threadpool;
