//! Substrate layer: everything the framework needs that the offline vendor
//! set does not provide (see DESIGN.md §5, S19/S21).

pub mod alloc;
pub mod benchlib;
pub mod cli;
pub mod json;
pub mod log;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;
