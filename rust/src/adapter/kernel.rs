//! The vectorized scatter kernel layer (DESIGN.md §15).
//!
//! Every scatter in the crate — apply, snapshot+apply, restore, gather and
//! the one-pass A→B transition — bottoms out in the span kernels defined
//! here.  Each kernel has two executions selected by [`KernelDispatch`]:
//!
//! * **Scalar** — the exact loops the crate shipped with (one indexed
//!   load/store per slot).  This is the reference semantics.
//! * **Simd** — a portable fixed-width abstraction: sorted SHiRA supports
//!   decompose into *row runs* of consecutive flat indices, and within a
//!   run the target slots are contiguous, so the kernel sweeps
//!   [`LANES`]-wide `[f32; LANES]` chunks (load–FMA–store over plain
//!   arrays the autovectorizer lowers to vector registers — no nightly
//!   `std::simd`, no intrinsics) with a scalar tail.  Isolated slots
//!   (runs shorter than a chunk) take the same scalar gather path as
//!   before.
//!
//! Per-lane arithmetic is the *same expression* as the scalar loop
//! (`base + alpha * delta`, never a fused multiply-add the scalar path
//! wouldn't use), so for f32-resident deltas the two dispatches are
//! bit-identical on every path — property-tested here and gated before
//! timing in `bench_switch` Part 4.
//!
//! Run boundaries come either precomputed (a [`crate::adapter::sparse::RunPlan`]
//! built once per adapter alongside its `ShardPlan`, handed in as
//! [`Runs::Cuts`]) or detected on the fly ([`Runs::Detect`]) on paths that
//! have no plan in hand.  Both describe the same decomposition, so the
//! choice is purely a build-time-vs-scan-time tradeoff.
//!
//! Deltas are read through [`DeltaSource`], which abstracts f32-resident
//! (`F32Src`) and f16-resident (`F16Src`, dequantized lane-wise via the
//! exact `f16 → f32` widening in `adapter::io`) storage.  f16 residency
//! halves resident delta bytes; because widening is exact, serving an
//! f16-resident adapter is bit-identical to serving the f32 decode of the
//! same `v2-f16` file.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::adapter::io::f16_bits_to_f32;
use crate::adapter::sparse::{MAX_SHARDS, NONE_POS};

/// SIMD chunk width (f32 lanes per sweep step).  8 × f32 = one AVX2
/// register / two NEON registers; the `[f32; LANES]` chunk form lets the
/// autovectorizer pick the widest unit the target actually has.
pub const LANES: usize = 8;

/// Which execution of the span kernels to run.
///
/// Probed once per process (at the first [`ThreadPool`] construction —
/// see `util::threadpool`) from the `SHIRA_KERNEL` env var, overridable
/// with the `--kernel scalar|simd` CLI knob via [`force_dispatch`].
///
/// [`ThreadPool`]: crate::util::threadpool::ThreadPool
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelDispatch {
    /// Reference scalar loops (bit-identical twin of `Simd` for f32).
    Scalar,
    /// Row-run chunked sweeps with a scalar tail (the default).
    Simd,
}

impl KernelDispatch {
    /// Parse a CLI/env spelling (`"scalar"` / `"simd"`).
    pub fn parse(s: &str) -> Option<KernelDispatch> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelDispatch::Scalar),
            "simd" => Some(KernelDispatch::Simd),
            _ => None,
        }
    }

    /// Stable display name (`"scalar"` / `"simd"`).
    pub fn name(self) -> &'static str {
        match self {
            KernelDispatch::Scalar => "scalar",
            KernelDispatch::Simd => "simd",
        }
    }
}

/// 0 = unset, 1 = scalar, 2 = simd.
static DISPATCH: AtomicU8 = AtomicU8::new(0);

fn code_of(d: KernelDispatch) -> u8 {
    match d {
        KernelDispatch::Scalar => 1,
        KernelDispatch::Simd => 2,
    }
}

fn probe() -> KernelDispatch {
    match std::env::var("SHIRA_KERNEL") {
        Ok(v) => KernelDispatch::parse(&v).unwrap_or(KernelDispatch::Simd),
        Err(_) => KernelDispatch::Simd,
    }
}

/// The process-wide dispatch mode.  First call probes `SHIRA_KERNEL`
/// (default [`KernelDispatch::Simd`]); later calls return the settled
/// value.  Engines read this once at construction and keep their own
/// copy, so a late [`force_dispatch`] never changes a live engine.
pub fn active_dispatch() -> KernelDispatch {
    match DISPATCH.load(Ordering::Relaxed) {
        1 => KernelDispatch::Scalar,
        2 => KernelDispatch::Simd,
        _ => {
            let probed = probe();
            // Keep whichever write (probe or a racing force) lands first.
            let _ = DISPATCH.compare_exchange(
                0,
                code_of(probed),
                Ordering::Relaxed,
                Ordering::Relaxed,
            );
            if DISPATCH.load(Ordering::Relaxed) == 1 {
                KernelDispatch::Scalar
            } else {
                KernelDispatch::Simd
            }
        }
    }
}

/// Override the process-wide dispatch (the `--kernel` CLI knob).  Takes
/// effect for engines constructed afterwards.
pub fn force_dispatch(d: KernelDispatch) {
    DISPATCH.store(code_of(d), Ordering::Relaxed);
}

/// The one home of the scalar/parallel dispatch thresholds shared by the
/// switch and fusion engines (satellite of ISSUE 8: previously duplicated
/// as loose constants in `adapter::sparse`, which are now deprecated
/// aliases of these fields).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelConfig {
    /// Below this many touched entries per operation, shard dispatch
    /// overhead exceeds the scatter itself and engines stay serial.
    pub par_min_nnz: usize,
    /// Target entries per shard (≈ a few cache-resident strides of work).
    pub nnz_per_shard: usize,
    /// Hard cap on shards per tensor (`ShardPlan` is fixed-size).
    pub max_shards: usize,
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig {
            par_min_nnz: 4096,
            nnz_per_shard: 2048,
            max_shards: MAX_SHARDS,
        }
    }
}

impl KernelConfig {
    /// Shard count for an `nnz`-entry scatter on a `threads`-wide pool.
    pub fn shards_for(&self, nnz: usize, threads: usize) -> usize {
        (nnz / self.nnz_per_shard)
            .max(1)
            .min(threads * 2)
            .min(self.max_shards)
    }

    /// True when an `nnz`-entry operation should dispatch parallel.
    pub fn parallel_worthwhile(&self, nnz: usize) -> bool {
        nnz >= self.par_min_nnz
    }
}

/// The crate-wide [`KernelConfig`] (one definition, so the switch and
/// fusion engines' cutoffs cannot drift apart).
pub fn config() -> KernelConfig {
    KernelConfig::default()
}

// ---------------------------------------------------------------------------
// Delta sources
// ---------------------------------------------------------------------------

/// Abstraction over where delta values live: f32-resident (`F32Src`) or
/// f16-resident (`F16Src`, widened lane-wise on read).  `Copy` raw-pointer
/// wrappers so span kernels stay monomorphized and allocation-free.
pub(crate) trait DeltaSource: Copy {
    /// Read delta value `j` as f32.
    ///
    /// # Safety
    /// `j` must be in-bounds for the underlying array.
    unsafe fn get(self, j: usize) -> f32;
}

/// f32-resident delta values.
#[derive(Clone, Copy)]
pub(crate) struct F32Src(pub *const f32);

// SAFETY: plain read-only pointer into a buffer the caller keeps alive
// and does not mutate for the duration of the scoped dispatch.
unsafe impl Send for F32Src {}
unsafe impl Sync for F32Src {}

impl DeltaSource for F32Src {
    #[inline(always)]
    unsafe fn get(self, j: usize) -> f32 {
        *self.0.add(j)
    }
}

/// f16-resident delta values (raw IEEE 754 binary16 bits), dequantized on
/// read with the exact widening conversion — so every kernel result is
/// bit-identical to running the f32 decode of the same file.
#[derive(Clone, Copy)]
pub(crate) struct F16Src(pub *const u16);

// SAFETY: as for `F32Src`.
unsafe impl Send for F16Src {}
unsafe impl Sync for F16Src {}

impl DeltaSource for F16Src {
    #[inline(always)]
    unsafe fn get(self, j: usize) -> f32 {
        f16_bits_to_f32(*self.0.add(j))
    }
}

// ---------------------------------------------------------------------------
// Run decomposition
// ---------------------------------------------------------------------------

/// How a span kernel learns the row-run decomposition of its `[lo, hi)`
/// index range.
#[derive(Clone, Copy)]
pub(crate) enum Runs {
    /// Detect maximal consecutive-index runs on the fly (paths with no
    /// precomputed plan in hand: serial one-shots, plan-mismatch
    /// fallbacks).
    Detect,
    /// Precomputed cut array covering exactly `[lo, hi)`:
    /// `cuts[0] == lo`, `cuts[len-1] == hi`, and indices are consecutive
    /// within each `[cuts[r], cuts[r+1])` (see `sparse::RunPlan::span`).
    Cuts {
        /// First cut (== `lo`).
        ptr: *const u32,
        /// Number of cuts (runs + 1; `len == 1` means an empty span).
        len: usize,
    },
}

// SAFETY: the cut array is owned by a plan the caller keeps alive across
// the scoped dispatch and is read-only.
unsafe impl Send for Runs {}
unsafe impl Sync for Runs {}

/// Internal iterator over maximal consecutive runs of `idx[lo..hi)`.
/// Plain struct (not `Iterator`) so `next_run` can be an `unsafe fn`
/// inside the kernels' existing unsafe contract.
struct RunIter {
    idx: *const u32,
    pos: usize,
    hi: usize,
    /// Null ⇒ detect mode.
    cuts: *const u32,
    cut_i: usize,
}

impl RunIter {
    #[inline(always)]
    fn new(idx: *const u32, lo: usize, hi: usize, runs: Runs) -> RunIter {
        match runs {
            Runs::Detect => RunIter {
                idx,
                pos: lo,
                hi,
                cuts: std::ptr::null(),
                cut_i: 0,
            },
            Runs::Cuts { ptr, len } => {
                debug_assert!(len >= 1);
                RunIter {
                    idx,
                    pos: lo,
                    hi,
                    cuts: ptr,
                    cut_i: 1,
                }
            }
        }
    }

    /// Next run `[s, e)`, or `None` when the span is exhausted.
    ///
    /// # Safety
    /// `idx[lo..hi)` (detect mode) / the cut array (cuts mode) must be
    /// live and in-bounds.
    #[inline(always)]
    unsafe fn next_run(&mut self) -> Option<(usize, usize)> {
        if self.pos >= self.hi {
            return None;
        }
        let s = self.pos;
        let e = if self.cuts.is_null() {
            let first = *self.idx.add(s) as usize;
            let mut e = s + 1;
            while e < self.hi && *self.idx.add(e) as usize == first + (e - s) {
                e += 1;
            }
            e
        } else {
            let e = *self.cuts.add(self.cut_i) as usize;
            self.cut_i += 1;
            debug_assert!(e > s && e <= self.hi);
            e
        };
        self.pos = e;
        Some((s, e))
    }
}

// ---------------------------------------------------------------------------
// Span kernels
// ---------------------------------------------------------------------------

/// `W.flat[idx[j]] += α·δ(j)` over `[lo, hi)`.
///
/// # Safety
/// `idx[lo..hi)` must be unique, in-bounds for `w` and for the delta
/// source; ranges handed to concurrent callers must be disjoint; in cuts
/// mode `runs` must describe exactly `[lo, hi)`.
#[allow(clippy::needless_range_loop)]
pub(crate) unsafe fn apply_span<D: DeltaSource>(
    dispatch: KernelDispatch,
    idx: *const u32,
    delta: D,
    w: *mut f32,
    alpha: f32,
    lo: usize,
    hi: usize,
    runs: Runs,
) {
    match dispatch {
        KernelDispatch::Scalar => {
            for j in lo..hi {
                let i = *idx.add(j) as usize;
                *w.add(i) += alpha * delta.get(j);
            }
        }
        KernelDispatch::Simd => {
            let mut it = RunIter::new(idx, lo, hi, runs);
            while let Some((s, e)) = it.next_run() {
                let wp = w.add(*idx.add(s) as usize);
                let n = e - s;
                let chunks = n / LANES;
                for c in 0..chunks {
                    let o = c * LANES;
                    let mut wv = [0f32; LANES];
                    let mut dv = [0f32; LANES];
                    for l in 0..LANES {
                        wv[l] = *wp.add(o + l);
                        dv[l] = delta.get(s + o + l);
                    }
                    for l in 0..LANES {
                        // Same expression as the scalar loop — no FMA
                        // contraction, so the dispatches stay bit-equal.
                        wv[l] += alpha * dv[l];
                    }
                    for l in 0..LANES {
                        *wp.add(o + l) = wv[l];
                    }
                }
                for t in (chunks * LANES)..n {
                    *wp.add(t) += alpha * delta.get(s + t);
                }
            }
        }
    }
}

/// Fused snapshot-then-apply over `[lo, hi)`: `snap[j] = W.flat[idx[j]]`,
/// then `W.flat[idx[j]] = snap[j] + α·δ(j)`.
///
/// # Safety
/// As [`apply_span`]; additionally `snap` slot `j` must be valid and
/// written by exactly one caller.
#[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
pub(crate) unsafe fn snapshot_apply_span<D: DeltaSource>(
    dispatch: KernelDispatch,
    idx: *const u32,
    delta: D,
    w: *mut f32,
    snap: *mut f32,
    alpha: f32,
    lo: usize,
    hi: usize,
    runs: Runs,
) {
    match dispatch {
        KernelDispatch::Scalar => {
            for j in lo..hi {
                let i = *idx.add(j) as usize;
                let wp = w.add(i);
                let base = *wp;
                *snap.add(j) = base;
                *wp = base + alpha * delta.get(j);
            }
        }
        KernelDispatch::Simd => {
            let mut it = RunIter::new(idx, lo, hi, runs);
            while let Some((s, e)) = it.next_run() {
                let wp = w.add(*idx.add(s) as usize);
                let sp = snap.add(s);
                let n = e - s;
                let chunks = n / LANES;
                for c in 0..chunks {
                    let o = c * LANES;
                    let mut bv = [0f32; LANES];
                    let mut dv = [0f32; LANES];
                    for l in 0..LANES {
                        bv[l] = *wp.add(o + l);
                        dv[l] = delta.get(s + o + l);
                    }
                    for l in 0..LANES {
                        *sp.add(o + l) = bv[l];
                    }
                    for l in 0..LANES {
                        *wp.add(o + l) = bv[l] + alpha * dv[l];
                    }
                }
                for t in (chunks * LANES)..n {
                    let wpt = wp.add(t);
                    let base = *wpt;
                    *sp.add(t) = base;
                    *wpt = base + alpha * delta.get(s + t);
                }
            }
        }
    }
}

/// Snapshot restore over `[lo, hi)`: `W.flat[idx[j]] = snap[j]`.
///
/// # Safety
/// As [`apply_span`]; `snap[lo..hi)` must be live.
pub(crate) unsafe fn restore_span(
    dispatch: KernelDispatch,
    idx: *const u32,
    w: *mut f32,
    snap: *const f32,
    lo: usize,
    hi: usize,
    runs: Runs,
) {
    match dispatch {
        KernelDispatch::Scalar => {
            for j in lo..hi {
                *w.add(*idx.add(j) as usize) = *snap.add(j);
            }
        }
        KernelDispatch::Simd => {
            let mut it = RunIter::new(idx, lo, hi, runs);
            while let Some((s, e)) = it.next_run() {
                // A run is a straight contiguous copy (pure stores of the
                // snapshotted bits — trivially bit-identical).
                let wp = w.add(*idx.add(s) as usize);
                std::ptr::copy_nonoverlapping(snap.add(s), wp, e - s);
            }
        }
    }
}

/// Gather over `[lo, hi)`: `out[j] = W.flat[idx[j]]`.
///
/// # Safety
/// `idx[lo..hi)` in-bounds for `w`; `out` slot `j` valid and written by
/// exactly one caller.
pub(crate) unsafe fn gather_span(
    dispatch: KernelDispatch,
    idx: *const u32,
    w: *const f32,
    out: *mut f32,
    lo: usize,
    hi: usize,
    runs: Runs,
) {
    match dispatch {
        KernelDispatch::Scalar => {
            for j in lo..hi {
                *out.add(j) = *w.add(*idx.add(j) as usize);
            }
        }
        KernelDispatch::Simd => {
            let mut it = RunIter::new(idx, lo, hi, runs);
            while let Some((s, e)) = it.next_run() {
                let wp = w.add(*idx.add(s) as usize);
                std::ptr::copy_nonoverlapping(wp, out.add(s), e - s);
            }
        }
    }
}

/// One-pass A→B transition over union slots `[lo, hi)` (the three-class
/// walk documented on `sparse::TransitionPlan`):
///
/// * A-only: `W = snap_a[ap]` (restore)
/// * B-only: `snap_b[bp] = W; W += α·δ_B(bp)`
/// * overlap: `snap_b[bp] = snap_a[ap]; W = snap_a[ap] + α·δ_B(bp)`
///
/// The SIMD execution additionally segments each consecutive union run by
/// slot class: within a uniform-class segment `a_pos`/`b_pos` advance by
/// one per slot, so A-only segments are contiguous copies from `snap_a`,
/// B-only segments are contiguous snapshot+apply sweeps, and overlap
/// segments are contiguous `snap_a`-sourced sweeps.
///
/// # Safety
/// `union_idx[lo..hi)` unique and in-bounds for `w`; `a_pos`/`b_pos`
/// entries `NONE_POS` or in-bounds for `snap_a` / (`snap_b`, `delta_b`);
/// concurrent ranges disjoint; in cuts mode `runs` must describe exactly
/// `[lo, hi)` of `union_idx`.
#[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
pub(crate) unsafe fn transition_span<D: DeltaSource>(
    dispatch: KernelDispatch,
    union_idx: *const u32,
    a_pos: *const u32,
    b_pos: *const u32,
    delta_b: D,
    w: *mut f32,
    snap_a: *const f32,
    snap_b: *mut f32,
    alpha: f32,
    lo: usize,
    hi: usize,
    runs: Runs,
) {
    match dispatch {
        KernelDispatch::Scalar => {
            for s in lo..hi {
                let i = *union_idx.add(s) as usize;
                let ap = *a_pos.add(s);
                let bp = *b_pos.add(s);
                if bp != NONE_POS {
                    let base = if ap != NONE_POS {
                        *snap_a.add(ap as usize)
                    } else {
                        *w.add(i)
                    };
                    *snap_b.add(bp as usize) = base;
                    *w.add(i) = base + alpha * delta_b.get(bp as usize);
                } else {
                    *w.add(i) = *snap_a.add(ap as usize);
                }
            }
        }
        KernelDispatch::Simd => {
            let mut it = RunIter::new(union_idx, lo, hi, runs);
            while let Some((rs, re)) = it.next_run() {
                let mut s = rs;
                while s < re {
                    // Extend the uniform-class segment [s, e).
                    let has_a = *a_pos.add(s) != NONE_POS;
                    let has_b = *b_pos.add(s) != NONE_POS;
                    let mut e = s + 1;
                    while e < re
                        && (*a_pos.add(e) != NONE_POS) == has_a
                        && (*b_pos.add(e) != NONE_POS) == has_b
                    {
                        e += 1;
                    }
                    let n = e - s;
                    let wp = w.add(*union_idx.add(s) as usize);
                    if !has_b {
                        // A-only: contiguous restore from snap_a.
                        let ap0 = *a_pos.add(s) as usize;
                        std::ptr::copy_nonoverlapping(snap_a.add(ap0), wp, n);
                    } else if !has_a {
                        // B-only: live values are the base.
                        let bp0 = *b_pos.add(s) as usize;
                        let sb = snap_b.add(bp0);
                        let chunks = n / LANES;
                        for c in 0..chunks {
                            let o = c * LANES;
                            let mut bv = [0f32; LANES];
                            let mut dv = [0f32; LANES];
                            for l in 0..LANES {
                                bv[l] = *wp.add(o + l);
                                dv[l] = delta_b.get(bp0 + o + l);
                            }
                            for l in 0..LANES {
                                *sb.add(o + l) = bv[l];
                            }
                            for l in 0..LANES {
                                *wp.add(o + l) = bv[l] + alpha * dv[l];
                            }
                        }
                        for t in (chunks * LANES)..n {
                            let wpt = wp.add(t);
                            let base = *wpt;
                            *sb.add(t) = base;
                            *wpt = base + alpha * delta_b.get(bp0 + t);
                        }
                    } else {
                        // Overlap: the base is A's snapshot, not the live
                        // value.
                        let ap0 = *a_pos.add(s) as usize;
                        let bp0 = *b_pos.add(s) as usize;
                        let sa = snap_a.add(ap0);
                        let sb = snap_b.add(bp0);
                        let chunks = n / LANES;
                        for c in 0..chunks {
                            let o = c * LANES;
                            let mut bv = [0f32; LANES];
                            let mut dv = [0f32; LANES];
                            for l in 0..LANES {
                                bv[l] = *sa.add(o + l);
                                dv[l] = delta_b.get(bp0 + o + l);
                            }
                            for l in 0..LANES {
                                *sb.add(o + l) = bv[l];
                            }
                            for l in 0..LANES {
                                *wp.add(o + l) = bv[l] + alpha * dv[l];
                            }
                        }
                        for t in (chunks * LANES)..n {
                            let base = *sa.add(t);
                            *sb.add(t) = base;
                            *wp.add(t) = base + alpha * delta_b.get(bp0 + t);
                        }
                    }
                    s = e;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::io::f32_to_f16_bits;
    use crate::adapter::sparse::{RunPlan, SparseDelta, TransitionPlan};
    use crate::util::proptest as pt;
    use crate::util::rng::Rng;

    fn both() -> [KernelDispatch; 2] {
        [KernelDispatch::Scalar, KernelDispatch::Simd]
    }

    /// Random sorted unique support with tunable run structure:
    /// `style` 0 = one fully-contiguous block, 1 = uniform scatter,
    /// 2 = clustered short runs.
    fn support(rng: &mut Rng, numel: usize, k: usize, style: usize) -> Vec<u32> {
        match style {
            0 => {
                let start = rng.below(numel - k + 1);
                (start as u32..(start + k) as u32).collect()
            }
            1 => rng.sample_indices(numel, k),
            _ => {
                let mut set = std::collections::BTreeSet::new();
                while set.len() < k {
                    let start = rng.below(numel);
                    let run = 1 + rng.below(2 * LANES);
                    for i in start..(start + run).min(numel) {
                        if set.len() >= k {
                            break;
                        }
                        set.insert(i as u32);
                    }
                }
                set.into_iter().collect()
            }
        }
    }

    #[test]
    fn dispatch_parse_and_name_roundtrip() {
        for d in both() {
            assert_eq!(KernelDispatch::parse(d.name()), Some(d));
        }
        assert_eq!(KernelDispatch::parse("SIMD"), Some(KernelDispatch::Simd));
        assert_eq!(KernelDispatch::parse("nope"), None);
    }

    #[test]
    fn config_matches_legacy_constants() {
        let c = config();
        assert_eq!(c.par_min_nnz, 4096);
        assert_eq!(c.nnz_per_shard, 2048);
        assert_eq!(c.max_shards, MAX_SHARDS);
        assert!(c.parallel_worthwhile(4096));
        assert!(!c.parallel_worthwhile(4095));
        assert_eq!(c.shards_for(0, 4), 1);
        assert_eq!(c.shards_for(100_000, 4), 8);
        assert_eq!(c.shards_for(1 << 30, 1024), MAX_SHARDS);
    }

    #[test]
    fn run_iter_detect_finds_maximal_runs() {
        let idx: Vec<u32> = vec![3, 4, 5, 9, 10, 20, 31, 32, 33, 34];
        let mut it = RunIter::new(idx.as_ptr(), 0, idx.len(), Runs::Detect);
        let mut got = Vec::new();
        unsafe {
            while let Some(r) = it.next_run() {
                got.push(r);
            }
        }
        assert_eq!(got, vec![(0, 3), (3, 5), (5, 6), (6, 10)]);
    }

    #[test]
    fn run_iter_cuts_matches_detect() {
        let mut rng = Rng::new(101);
        for style in 0..3 {
            for &k in &[1usize, 7, 8, 9, 40, 200] {
                let idx = support(&mut rng, 4096, k, style);
                let d = SparseDelta::new(64, 64, idx.clone(), vec![0.0; k]);
                let plan = d.shard(1);
                let runs = RunPlan::build(&idx, &plan);
                let (ptr, len) = runs.span(0, k);
                let mut a = RunIter::new(idx.as_ptr(), 0, k, Runs::Detect);
                let mut b = RunIter::new(idx.as_ptr(), 0, k, Runs::Cuts { ptr, len });
                unsafe {
                    loop {
                        let (x, y) = (a.next_run(), b.next_run());
                        assert_eq!(x, y, "style={style} k={k}");
                        if x.is_none() {
                            break;
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn prop_simd_bit_identical_to_scalar_all_kernels() {
        // The tentpole invariant, at the kernel level: for random supports
        // across run-structure styles, lane remainders, and shard cuts,
        // every SIMD span kernel produces the bytes of its scalar twin.
        pt::forall(
            201,
            40,
            |r| {
                let style = r.below(3);
                let k = 1 + r.below(600);
                let alpha = -2.0 + 4.0 * r.uniform_f32();
                let shards = 1 + r.below(6);
                (r.next_u64(), style, k, alpha, shards)
            },
            |&(seed, style, k, alpha, shards)| {
                let mut rng = Rng::new(seed);
                let (rows, cols) = (64usize, 64usize);
                let idx = support(&mut rng, rows * cols, k, style);
                let k = idx.len();
                let mut delta = vec![0.0f32; k];
                rng.fill_normal(&mut delta, 0.0, 1.0);
                let d = SparseDelta::new(rows, cols, idx, delta);
                let mut w0 = vec![0.0f32; rows * cols];
                rng.fill_normal(&mut w0, 0.0, 1.0);
                let plan = d.shard(shards);
                let runs = RunPlan::build(&d.idx, &plan);

                // scalar reference for each kernel
                let mut w_ref = w0.clone();
                let mut snap_ref = vec![0.0f32; k];
                let mut gat_ref = vec![0.0f32; k];
                unsafe {
                    snapshot_apply_span(
                        KernelDispatch::Scalar,
                        d.idx.as_ptr(),
                        F32Src(d.delta.as_ptr()),
                        w_ref.as_mut_ptr(),
                        snap_ref.as_mut_ptr(),
                        alpha,
                        0,
                        k,
                        Runs::Detect,
                    );
                    gather_span(
                        KernelDispatch::Scalar,
                        d.idx.as_ptr(),
                        w_ref.as_ptr(),
                        gat_ref.as_mut_ptr(),
                        0,
                        k,
                        Runs::Detect,
                    );
                }

                // SIMD over the sharded spans with precomputed cuts, plus
                // apply/restore round-trip.
                let mut w = w0.clone();
                let mut snap = vec![0.0f32; k];
                let mut gat = vec![0.0f32; k];
                for s in 0..plan.len() {
                    let (lo, hi) = plan.range(s);
                    let (ptr, len) = runs.span(lo, hi);
                    let rc = Runs::Cuts { ptr, len };
                    unsafe {
                        snapshot_apply_span(
                            KernelDispatch::Simd,
                            d.idx.as_ptr(),
                            F32Src(d.delta.as_ptr()),
                            w.as_mut_ptr(),
                            snap.as_mut_ptr(),
                            alpha,
                            lo,
                            hi,
                            rc,
                        );
                        gather_span(
                            KernelDispatch::Simd,
                            d.idx.as_ptr(),
                            w.as_ptr(),
                            gat.as_mut_ptr(),
                            lo,
                            hi,
                            rc,
                        );
                    }
                }
                if w != w_ref || snap != snap_ref || gat != gat_ref {
                    return false;
                }

                // restore (SIMD, detect mode) must return w0 exactly, and
                // apply_span must equal snapshot_apply's weight effect.
                let mut w2 = w0.clone();
                unsafe {
                    apply_span(
                        KernelDispatch::Simd,
                        d.idx.as_ptr(),
                        F32Src(d.delta.as_ptr()),
                        w2.as_mut_ptr(),
                        alpha,
                        0,
                        k,
                        Runs::Detect,
                    );
                    restore_span(
                        KernelDispatch::Simd,
                        d.idx.as_ptr(),
                        w.as_mut_ptr(),
                        snap.as_ptr(),
                        0,
                        k,
                        Runs::Detect,
                    );
                }
                w2 == w_ref && w == w0
            },
        );
    }

    #[test]
    fn prop_transition_span_simd_matches_scalar_all_overlap_classes() {
        pt::forall(
            202,
            30,
            |r| {
                let style_a = r.below(3);
                let style_b = r.below(3);
                let ka = 1 + r.below(400);
                let kb = 1 + r.below(400);
                let alpha = -2.0 + 4.0 * r.uniform_f32();
                (r.next_u64(), style_a, style_b, ka, kb, alpha)
            },
            |&(seed, style_a, style_b, ka, kb, alpha)| {
                let mut rng = Rng::new(seed);
                let (rows, cols) = (48usize, 48usize);
                let numel = rows * cols;
                let ia = support(&mut rng, numel, ka, style_a);
                let ib = support(&mut rng, numel, kb, style_b);
                let mut da = vec![0.0f32; ia.len()];
                let mut db = vec![0.0f32; ib.len()];
                rng.fill_normal(&mut da, 0.0, 1.0);
                rng.fill_normal(&mut db, 0.0, 1.0);
                let a = SparseDelta::new(rows, cols, ia, da);
                let b = SparseDelta::new(rows, cols, ib, db);
                let tp = TransitionPlan::build(&a, &b, 3);
                let mut w0 = vec![0.0f32; numel];
                rng.fill_normal(&mut w0, 0.0, 1.0);
                let mut wt = crate::model::tensor::Tensor2::zeros(rows, cols);
                wt.data.copy_from_slice(&w0);
                let snap_a = a.snapshot(&wt);
                a.apply(&mut wt, 0.9);

                let (ui, ap, bp) = tp.raw_parts();
                let un = tp.union_nnz();
                let run = |disp: KernelDispatch| {
                    let mut w = wt.data.clone();
                    let mut snap_b = vec![0.0f32; b.nnz()];
                    unsafe {
                        transition_span(
                            disp,
                            ui,
                            ap,
                            bp,
                            F32Src(b.delta.as_ptr()),
                            w.as_mut_ptr(),
                            snap_a.as_ptr(),
                            snap_b.as_mut_ptr(),
                            alpha,
                            0,
                            un,
                            Runs::Detect,
                        );
                    }
                    (w, snap_b)
                };
                let (w_s, sb_s) = run(KernelDispatch::Scalar);
                let (w_v, sb_v) = run(KernelDispatch::Simd);
                w_s == w_v && sb_s == sb_v
            },
        );
    }

    #[test]
    fn prop_f16_source_matches_f32_of_decoded_bits() {
        // f16-resident apply ≡ f32-apply of the decoded (widened) values:
        // the widening is exact, so both dispatches and both sources agree
        // bit for bit.
        pt::forall(
            203,
            30,
            |r| {
                let style = r.below(3);
                let k = 1 + r.below(300);
                let alpha = -2.0 + 4.0 * r.uniform_f32();
                (r.next_u64(), style, k, alpha)
            },
            |&(seed, style, k, alpha)| {
                let mut rng = Rng::new(seed);
                let numel = 2048usize;
                let idx = support(&mut rng, numel, k, style);
                let k = idx.len();
                let mut vals = vec![0.0f32; k];
                rng.fill_normal(&mut vals, 0.0, 1.0);
                let bits: Vec<u16> = vals.iter().map(|&v| f32_to_f16_bits(v)).collect();
                let decoded: Vec<f32> = bits.iter().map(|&b| f16_bits_to_f32(b)).collect();
                let mut w0 = vec![0.0f32; numel];
                rng.fill_normal(&mut w0, 0.0, 1.0);
                both().iter().all(|&disp| {
                    let mut w16 = w0.clone();
                    let mut s16 = vec![0.0f32; k];
                    let mut w32 = w0.clone();
                    let mut s32 = vec![0.0f32; k];
                    unsafe {
                        snapshot_apply_span(
                            disp,
                            idx.as_ptr(),
                            F16Src(bits.as_ptr()),
                            w16.as_mut_ptr(),
                            s16.as_mut_ptr(),
                            alpha,
                            0,
                            k,
                            Runs::Detect,
                        );
                        snapshot_apply_span(
                            disp,
                            idx.as_ptr(),
                            F32Src(decoded.as_ptr()),
                            w32.as_mut_ptr(),
                            s32.as_mut_ptr(),
                            alpha,
                            0,
                            k,
                            Runs::Detect,
                        );
                    }
                    w16 == w32 && s16 == s32
                })
            },
        );
    }

    #[test]
    fn force_dispatch_round_trips() {
        // Note: other tests read `active_dispatch()` only through engine
        // constructors that tolerate either mode (both are bit-identical
        // for f32), so flipping the global here is safe.
        let before = active_dispatch();
        force_dispatch(KernelDispatch::Scalar);
        assert_eq!(active_dispatch(), KernelDispatch::Scalar);
        force_dispatch(KernelDispatch::Simd);
        assert_eq!(active_dispatch(), KernelDispatch::Simd);
        force_dispatch(before);
    }
}
