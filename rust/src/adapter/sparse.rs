//! Sparse-delta algebra: the COO representation of a SHiRA adapter tensor
//! and the scatter hot path (paper §3.2, Fig. 3, Fig. 5).
//!
//! Representation: sorted unique flat indices (u32) + per-index delta
//! values (new_weight − base_weight at α = 1).  Application at strength α
//! is `W.flat[idx[i]] += α·delta[i]`; exact revert uses a base-value
//! snapshot taken at apply time (float-exact, unlike LoRA's W−αAB unfuse).

use crate::model::tensor::Tensor2;

/// Sparse delta for one weight tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseDelta {
    pub rows: usize,
    pub cols: usize,
    /// Sorted, unique flat indices (row-major).
    pub idx: Vec<u32>,
    /// delta[i] = finetuned_value − base_value at idx[i].
    pub delta: Vec<f32>,
}

impl SparseDelta {
    pub fn new(rows: usize, cols: usize, idx: Vec<u32>, delta: Vec<f32>) -> Self {
        assert_eq!(idx.len(), delta.len());
        debug_assert!(idx.windows(2).all(|w| w[0] < w[1]), "indices sorted+unique");
        debug_assert!(idx.iter().all(|&i| (i as usize) < rows * cols));
        SparseDelta {
            rows,
            cols,
            idx,
            delta,
        }
    }

    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / self.numel() as f64
    }

    /// Bytes to store the adapter tensor (idx u32 + delta f32).
    pub fn nbytes(&self) -> usize {
        self.nnz() * 8
    }

    /// Build from a finetuned tensor vs its base: S = W' − W, keeping the
    /// entries at `idx` (the mask support).
    pub fn from_diff(base: &Tensor2, tuned_vals_at_idx: &[f32], idx: Vec<u32>) -> Self {
        let delta = idx
            .iter()
            .zip(tuned_vals_at_idx.iter())
            .map(|(&i, &v)| v - base.data[i as usize])
            .collect();
        SparseDelta::new(base.rows, base.cols, idx, delta)
    }

    /// The scatter hot path: `W.flat[idx[i]] += α·delta[i]`.
    ///
    /// Indices are sorted, so writes walk memory monotonically — the
    /// cache-friendly order that makes SHiRA switching ~10× faster than a
    /// dense LoRA fuse at large dims (Fig. 5).
    #[inline]
    pub fn apply(&self, w: &mut Tensor2, alpha: f32) {
        debug_assert_eq!(w.rows, self.rows);
        debug_assert_eq!(w.cols, self.cols);
        let data = &mut w.data[..];
        for (&i, &d) in self.idx.iter().zip(self.delta.iter()) {
            // SAFETY: idx entries are validated < rows*cols at construction.
            unsafe {
                *data.get_unchecked_mut(i as usize) += alpha * d;
            }
        }
    }

    /// Snapshot the base values at this delta's support (for exact revert).
    pub fn snapshot(&self, w: &Tensor2) -> Vec<f32> {
        self.idx.iter().map(|&i| w.data[i as usize]).collect()
    }

    /// Exact revert: write back a snapshot taken before `apply`.
    pub fn restore(&self, w: &mut Tensor2, snapshot: &[f32]) {
        assert_eq!(snapshot.len(), self.nnz());
        let data = &mut w.data[..];
        for (&i, &s) in self.idx.iter().zip(snapshot.iter()) {
            unsafe {
                *data.get_unchecked_mut(i as usize) = s;
            }
        }
    }

    /// Gather current values at the support.
    pub fn gather(&self, w: &Tensor2) -> Vec<f32> {
        self.idx.iter().map(|&i| w.data[i as usize]).collect()
    }

    /// Naive multi-adapter fusion (paper Fig. 3b): index-union merge,
    /// summing deltas where supports overlap.
    pub fn merge(&self, other: &SparseDelta) -> SparseDelta {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut idx = Vec::with_capacity(self.nnz() + other.nnz());
        let mut delta = Vec::with_capacity(self.nnz() + other.nnz());
        let (mut a, mut b) = (0usize, 0usize);
        while a < self.nnz() || b < other.nnz() {
            let ia = self.idx.get(a).copied().unwrap_or(u32::MAX);
            let ib = other.idx.get(b).copied().unwrap_or(u32::MAX);
            if ia < ib {
                idx.push(ia);
                delta.push(self.delta[a]);
                a += 1;
            } else if ib < ia {
                idx.push(ib);
                delta.push(other.delta[b]);
                b += 1;
            } else {
                idx.push(ia);
                delta.push(self.delta[a] + other.delta[b]);
                a += 1;
                b += 1;
            }
        }
        SparseDelta::new(self.rows, self.cols, idx, delta)
    }

    /// Scale the delta (the paper's α baked in permanently).
    pub fn scaled(&self, alpha: f32) -> SparseDelta {
        SparseDelta {
            rows: self.rows,
            cols: self.cols,
            idx: self.idx.clone(),
            delta: self.delta.iter().map(|d| d * alpha).collect(),
        }
    }

    /// |support(self) ∩ support(other)| — the collision count that drives
    /// multi-adapter interference (paper §3.2).
    pub fn overlap(&self, other: &SparseDelta) -> usize {
        let (mut a, mut b, mut n) = (0usize, 0usize, 0usize);
        while a < self.nnz() && b < other.nnz() {
            match self.idx[a].cmp(&other.idx[b]) {
                std::cmp::Ordering::Less => a += 1,
                std::cmp::Ordering::Greater => b += 1,
                std::cmp::Ordering::Equal => {
                    n += 1;
                    a += 1;
                    b += 1;
                }
            }
        }
        n
    }

    /// Number of nonzero entries of `selfᵀ · other` (both viewed as dense
    /// n×m matrices with these sparse supports).  An entry (c1, c2) of the
    /// product is nonzero only if some row r has self[r,c1] ≠ 0 and
    /// other[r,c2] ≠ 0 — the orthogonality diagnostic of paper §3.2.
    /// Returns (nnz, total = m²).
    pub fn ata_nnz(&self, other: &SparseDelta) -> (usize, usize) {
        use std::collections::HashSet;
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        // group columns by row for both supports
        let mut rows_self: Vec<Vec<u32>> = vec![Vec::new(); self.rows];
        for &i in &self.idx {
            rows_self[(i as usize) / self.cols].push(i % self.cols as u32);
        }
        let mut rows_other: Vec<Vec<u32>> = vec![Vec::new(); other.rows];
        for &i in &other.idx {
            rows_other[(i as usize) / other.cols].push(i % other.cols as u32);
        }
        let mut pairs: HashSet<u64> = HashSet::new();
        for r in 0..self.rows {
            for &c1 in &rows_self[r] {
                for &c2 in &rows_other[r] {
                    pairs.insert((c1 as u64) << 32 | c2 as u64);
                }
            }
        }
        (pairs.len(), self.cols * self.cols)
    }

    /// Densify (tests / analysis only).
    pub fn to_dense(&self) -> Tensor2 {
        let mut t = Tensor2::zeros(self.rows, self.cols);
        for (&i, &d) in self.idx.iter().zip(self.delta.iter()) {
            t.data[i as usize] = d;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;
    use crate::util::rng::Rng;

    fn random_delta(rng: &mut Rng, rows: usize, cols: usize, k: usize) -> SparseDelta {
        let idx = rng.sample_indices(rows * cols, k);
        let mut delta = vec![0.0; k];
        rng.fill_normal(&mut delta, 0.0, 1.0);
        SparseDelta::new(rows, cols, idx, delta)
    }

    fn random_w(rng: &mut Rng, rows: usize, cols: usize) -> Tensor2 {
        let mut t = Tensor2::zeros(rows, cols);
        rng.fill_normal(&mut t.data, 0.0, 1.0);
        t
    }

    #[test]
    fn apply_changes_exactly_support() {
        let mut rng = Rng::new(1);
        let w0 = random_w(&mut rng, 16, 16);
        let d = random_delta(&mut rng, 16, 16, 10);
        let mut w = w0.clone();
        d.apply(&mut w, 1.0);
        let mut changed = 0;
        for i in 0..w.numel() {
            if w.data[i] != w0.data[i] {
                changed += 1;
                assert!(d.idx.contains(&(i as u32)));
            }
        }
        assert_eq!(changed, 10);
    }

    #[test]
    fn apply_alpha_scales() {
        let mut rng = Rng::new(2);
        let w0 = random_w(&mut rng, 8, 8);
        let d = random_delta(&mut rng, 8, 8, 5);
        let mut w_half = w0.clone();
        d.apply(&mut w_half, 0.5);
        for (j, &i) in d.idx.iter().enumerate() {
            let want = w0.data[i as usize] + 0.5 * d.delta[j];
            assert_eq!(w_half.data[i as usize], want);
        }
    }

    #[test]
    fn snapshot_restore_is_bit_exact() {
        let mut rng = Rng::new(3);
        let w0 = random_w(&mut rng, 32, 32);
        let d = random_delta(&mut rng, 32, 32, 64);
        let mut w = w0.clone();
        let snap = d.snapshot(&w);
        d.apply(&mut w, 1.7);
        assert!(w.max_abs_diff(&w0) > 0.0);
        d.restore(&mut w, &snap);
        assert_eq!(w.data, w0.data); // exact, not approx — the SHiRA claim
    }

    #[test]
    fn from_diff_roundtrip() {
        let mut rng = Rng::new(4);
        let base = random_w(&mut rng, 8, 12);
        let idx = rng.sample_indices(96, 9);
        let tuned: Vec<f32> = idx.iter().map(|&i| base.data[i as usize] + 2.0).collect();
        let d = SparseDelta::from_diff(&base, &tuned, idx.clone());
        let mut w = base.clone();
        d.apply(&mut w, 1.0);
        for (&i, &t) in idx.iter().zip(tuned.iter()) {
            assert!((w.data[i as usize] - t).abs() < 1e-6);
        }
    }

    #[test]
    fn merge_unions_and_sums() {
        let a = SparseDelta::new(2, 4, vec![0, 3, 5], vec![1.0, 2.0, 3.0]);
        let b = SparseDelta::new(2, 4, vec![3, 6], vec![10.0, 20.0]);
        let m = a.merge(&b);
        assert_eq!(m.idx, vec![0, 3, 5, 6]);
        assert_eq!(m.delta, vec![1.0, 12.0, 3.0, 20.0]);
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let a = SparseDelta::new(2, 4, vec![1, 2], vec![1.0, 2.0]);
        let e = SparseDelta::new(2, 4, vec![], vec![]);
        assert_eq!(a.merge(&e), a);
        assert_eq!(e.merge(&a), a);
    }

    #[test]
    fn overlap_counts_shared_support() {
        let a = SparseDelta::new(4, 4, vec![0, 1, 8], vec![1.0; 3]);
        let b = SparseDelta::new(4, 4, vec![1, 8, 9], vec![1.0; 3]);
        assert_eq!(a.overlap(&b), 2);
        assert_eq!(b.overlap(&a), 2);
        assert_eq!(a.overlap(&a), 3);
    }

    #[test]
    fn ata_sparse_vs_dense_shapes() {
        // Two 1%-sparse adapters: product should be overwhelmingly zero.
        let mut rng = Rng::new(5);
        let n = 64;
        let k = (n * n) / 100;
        let a = random_delta(&mut rng, n, n, k);
        let b = random_delta(&mut rng, n, n, k);
        let (nnz, total) = a.ata_nnz(&b);
        assert!(total == n * n);
        assert!(
            (nnz as f64) < 0.05 * total as f64,
            "sparse product unexpectedly dense: {nnz}/{total}"
        );
    }

    #[test]
    fn ata_nnz_exact_small() {
        // a has (r0,c0)=(0,1); b has (0,2),(1,3): product nonzero only (1,2).
        let a = SparseDelta::new(2, 4, vec![1], vec![1.0]);
        let b = SparseDelta::new(2, 4, vec![2, 7], vec![1.0, 1.0]);
        let (nnz, total) = a.ata_nnz(&b);
        assert_eq!(nnz, 1);
        assert_eq!(total, 16);
    }

    #[test]
    fn prop_merge_commutes_on_disjoint_supports() {
        pt::forall(
            7,
            40,
            |r| {
                let rows = 4 + r.below(8);
                let cols = 4 + r.below(8);
                let total = rows * cols;
                let k1 = 1 + r.below(total / 2);
                let extra = r.below(total / 2);
                let all = r.sample_indices(total, (k1 + 1 + extra).min(total));
                let split = k1.min(all.len() - 1).max(1);
                (rows, cols, all, split)
            },
            |(rows, cols, all, split)| {
                let (i1, i2) = all.split_at(*split);
                let d1 = SparseDelta::new(
                    *rows,
                    *cols,
                    i1.to_vec(),
                    i1.iter().map(|&i| i as f32).collect(),
                );
                let mut i2s = i2.to_vec();
                i2s.sort_unstable();
                let d2 = SparseDelta::new(
                    *rows,
                    *cols,
                    i2s.clone(),
                    i2s.iter().map(|&i| -(i as f32)).collect(),
                );
                d1.merge(&d2) == d2.merge(&d1)
            },
        );
    }

    #[test]
    fn prop_apply_revert_exact_for_any_alpha_sequence() {
        // Serving invariant (DESIGN.md §7): any interleaving of
        // apply/revert pairs leaves the base bit-identical.
        pt::forall(
            8,
            30,
            |r| {
                let alphas: Vec<f32> = (0..1 + r.below(4))
                    .map(|_| -2.0 + 4.0 * r.uniform_f32())
                    .collect();
                (r.next_u64(), alphas)
            },
            |(seed, alphas)| {
                let mut rng = Rng::new(*seed);
                let w0 = random_w(&mut rng, 16, 16);
                let mut w = w0.clone();
                for &a in alphas {
                    let d = random_delta(&mut rng, 16, 16, 8);
                    let snap = d.snapshot(&w);
                    d.apply(&mut w, a);
                    d.restore(&mut w, &snap);
                }
                w.data == w0.data
            },
        );
    }

    #[test]
    fn to_dense_matches_apply_on_zero_base() {
        let mut rng = Rng::new(9);
        let d = random_delta(&mut rng, 8, 8, 6);
        let mut w = Tensor2::zeros(8, 8);
        d.apply(&mut w, 1.0);
        assert_eq!(w, d.to_dense());
    }
}
