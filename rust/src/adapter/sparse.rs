//! Sparse-delta algebra: the COO representation of a SHiRA adapter tensor
//! and the scatter hot path (paper §3.2, Fig. 3, Fig. 5).
//!
//! Representation: sorted unique flat indices (u32) + per-index delta
//! values (new_weight − base_weight at α = 1).  Application at strength α
//! is `W.flat[idx[i]] += α·delta[i]`; exact revert uses a base-value
//! snapshot taken at apply time (float-exact, unlike LoRA's W−αAB unfuse).
//!
//! For multi-core switching the sorted index array can be partitioned into
//! a row-aligned [`ShardPlan`]: shards own disjoint row ranges of W, so
//! `apply`/`restore`/`gather`/`merge` run shard-parallel with disjoint
//! writes and no false sharing on the output cache lines (DESIGN.md §3).
//! Every parallel path is bit-identical to its serial counterpart: each
//! element is touched by exactly one shard and the per-element arithmetic
//! is unchanged.
//!
//! The per-element loops themselves live in [`crate::adapter::kernel`]
//! (DESIGN.md §15): every scatter here hands its span to a dispatch-
//! selected kernel (scalar reference or row-run SIMD sweeps).  A
//! [`RunPlan`] precomputes the consecutive-index run cuts alongside each
//! [`ShardPlan`] (the pair is a [`TensorPlan`]) so the hot engine paths
//! sweep contiguous runs without a detection pass.

use crate::adapter::kernel::{self, F16Src, F32Src, Runs};
use crate::model::tensor::Tensor2;
use crate::util::threadpool::{SendPtr, ThreadPool};

/// Hard cap on shards per tensor; keeps [`ShardPlan`] a fixed-size (heap-
/// allocation-free) value, which the zero-alloc switch path relies on.
pub const MAX_SHARDS: usize = 64;

/// Deprecated alias of [`kernel::KernelConfig::par_min_nnz`] — the
/// threshold now has one home shared by both engines.
#[deprecated(note = "read kernel::config().par_min_nnz instead")]
#[allow(dead_code)]
pub(crate) const PAR_MIN_NNZ: usize = 4096;

/// Deprecated alias of [`kernel::KernelConfig::nnz_per_shard`].
#[deprecated(note = "read kernel::config().nnz_per_shard instead")]
#[allow(dead_code)]
pub(crate) const NNZ_PER_SHARD: usize = 2048;

/// Shard count for an `nnz`-entry scatter on a `threads`-wide pool
/// (delegates to the crate-wide [`kernel::KernelConfig`]).
pub(crate) fn shards_for(nnz: usize, threads: usize) -> usize {
    kernel::config().shards_for(nnz, threads)
}

/// Row-aligned partition of a sorted index array into `n` contiguous
/// ranges with near-equal nnz.  `bounds[s]..bounds[s+1]` is shard `s`'s
/// range into `idx`/`delta`; boundaries are snapped up to row boundaries
/// of the underlying matrix so two shards never write the same row.
#[derive(Clone, Copy, Debug)]
pub struct ShardPlan {
    n_shards: usize,
    bounds: [usize; MAX_SHARDS + 1],
}

impl ShardPlan {
    /// Number of shards in the plan.
    pub fn len(&self) -> usize {
        self.n_shards
    }

    /// True when the plan holds no shards (never produced by `shard`).
    pub fn is_empty(&self) -> bool {
        self.n_shards == 0
    }

    /// Index range `[lo, hi)` of shard `s`.
    pub fn range(&self, s: usize) -> (usize, usize) {
        (self.bounds[s], self.bounds[s + 1])
    }

    /// Total entries covered (== nnz of the delta the plan was built for).
    pub fn total(&self) -> usize {
        self.bounds[self.n_shards]
    }
}

/// Precomputed row-run decomposition of a sorted support: the positions
/// where consecutive-index runs break, merged with the boundaries of the
/// [`ShardPlan`] it was built against, as one strictly increasing cut
/// array `[0, …, nnz]`.  [`RunPlan::span`] hands any shard range its cut
/// sub-array in O(log n), so the SIMD kernels sweep contiguous runs
/// without an on-the-fly detection pass (DESIGN.md §15).
#[derive(Clone, Debug)]
pub struct RunPlan {
    /// Strictly increasing; `cuts[0] == 0`, `cuts[last] == nnz` (empty
    /// support ⇒ just `[0]`), and every shard boundary appears.
    cuts: Vec<u32>,
}

impl RunPlan {
    /// Decompose `idx` (sorted unique) into maximal consecutive runs,
    /// cutting additionally at every boundary of `shards` so each shard's
    /// range is exactly covered by whole cut intervals.
    pub fn build(idx: &[u32], shards: &ShardPlan) -> RunPlan {
        debug_assert_eq!(shards.total(), idx.len());
        let nnz = idx.len();
        let mut cuts: Vec<u32> = Vec::with_capacity(shards.len() + 1);
        cuts.push(0);
        for p in 1..nnz {
            if idx[p] != idx[p - 1] + 1 {
                cuts.push(p as u32);
            }
        }
        if nnz > 0 {
            cuts.push(nnz as u32);
        }
        for s in 1..shards.len() {
            let b = shards.range(s).0 as u32;
            if let Err(i) = cuts.binary_search(&b) {
                cuts.insert(i, b);
            }
        }
        cuts.shrink_to_fit();
        RunPlan { cuts }
    }

    /// Number of cut intervals (runs after shard splitting).
    pub fn n_runs(&self) -> usize {
        self.cuts.len().saturating_sub(1)
    }

    /// Heap bytes held (plan-cache accounting).
    pub fn nbytes(&self) -> usize {
        self.cuts.len() * 4
    }

    /// The cut sub-array covering `[lo, hi)` as a `(first_cut, n_cuts)`
    /// pair for [`kernel::Runs::Cuts`].  `lo` and `hi` must be cut
    /// positions of this plan — shard boundaries of the plan it was built
    /// against always are.
    pub(crate) fn span(&self, lo: usize, hi: usize) -> (*const u32, usize) {
        let lo_i = self.cuts.partition_point(|&c| (c as usize) < lo);
        let hi_i = self.cuts.partition_point(|&c| (c as usize) < hi);
        debug_assert_eq!(self.cuts.get(lo_i).map(|&c| c as usize), Some(lo));
        debug_assert_eq!(self.cuts.get(hi_i).map(|&c| c as usize), Some(hi));
        // SAFETY: partition_point ≤ len, so the pointer stays inside (or
        // one past) the Vec's buffer.
        (unsafe { self.cuts.as_ptr().add(lo_i) }, hi_i - lo_i + 1)
    }
}

/// Everything the engines precompute per tensor for dispatch: the
/// row-aligned [`ShardPlan`] plus the [`RunPlan`] the SIMD kernels sweep.
#[derive(Clone, Debug)]
pub struct TensorPlan {
    /// Row-aligned shard partition (one wave slot per shard).
    pub shards: ShardPlan,
    /// Run cuts over the same support, aligned to the shard boundaries.
    pub runs: RunPlan,
}

impl TensorPlan {
    /// Build both plans for `d` at `n_shards`-wide dispatch.
    pub fn build(d: &SparseDelta, n_shards: usize) -> TensorPlan {
        TensorPlan::from_idx(&d.idx, d.cols, n_shards)
    }

    /// Build from any sorted unique support (shared with the f16-resident
    /// decode path, which never materializes a [`SparseDelta`]).
    pub fn from_idx(idx: &[u32], cols: usize, n_shards: usize) -> TensorPlan {
        let shards = shard_sorted(idx, cols, n_shards);
        let runs = RunPlan::build(idx, &shards);
        TensorPlan { shards, runs }
    }

    /// nnz covered (== support length both plans were built for).
    pub fn total(&self) -> usize {
        self.shards.total()
    }

    /// Heap bytes held (plan-cache accounting).
    pub fn nbytes(&self) -> usize {
        self.runs.nbytes() + std::mem::size_of::<TensorPlan>()
    }
}

/// Sparse delta for one weight tensor.
///
/// # Examples
///
/// Apply, then revert exactly from a snapshot (the SHiRA switching story):
///
/// ```
/// use shira::adapter::sparse::SparseDelta;
/// use shira::model::tensor::Tensor2;
///
/// let mut w = Tensor2::zeros(2, 4);
/// let d = SparseDelta::new(2, 4, vec![1, 6], vec![0.5, -2.0]);
/// let snap = d.snapshot(&w);
/// d.apply(&mut w, 1.0);
/// assert_eq!(w.data[1], 0.5);
/// assert_eq!(w.data[6], -2.0);
/// d.restore(&mut w, &snap);
/// assert!(w.data.iter().all(|&x| x == 0.0)); // bit-exact revert
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct SparseDelta {
    /// Rows of the target tensor.
    pub rows: usize,
    /// Columns of the target tensor.
    pub cols: usize,
    /// Sorted, unique flat indices (row-major).
    pub idx: Vec<u32>,
    /// delta[i] = finetuned_value − base_value at idx[i].
    pub delta: Vec<f32>,
}

impl SparseDelta {
    /// Build from sorted unique flat indices and their delta values.
    pub fn new(rows: usize, cols: usize, idx: Vec<u32>, delta: Vec<f32>) -> Self {
        assert_eq!(idx.len(), delta.len());
        debug_assert!(idx.windows(2).all(|w| w[0] < w[1]), "indices sorted+unique");
        debug_assert!(idx.iter().all(|&i| (i as usize) < rows * cols));
        SparseDelta {
            rows,
            cols,
            idx,
            delta,
        }
    }

    /// Number of nonzero entries.
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Elements of the target tensor (rows × cols).
    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    /// nnz / numel — the paper's 1–2% sparsity knob.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / self.numel() as f64
    }

    /// Bytes to store the adapter tensor (idx u32 + delta f32).
    pub fn nbytes(&self) -> usize {
        self.nnz() * 8
    }

    /// Build from a finetuned tensor vs its base: S = W' − W, keeping the
    /// entries at `idx` (the mask support).
    pub fn from_diff(base: &Tensor2, tuned_vals_at_idx: &[f32], idx: Vec<u32>) -> Self {
        let delta = idx
            .iter()
            .zip(tuned_vals_at_idx.iter())
            .map(|(&i, &v)| v - base.data[i as usize])
            .collect();
        SparseDelta::new(base.rows, base.cols, idx, delta)
    }

    // -- sharding ---------------------------------------------------------

    /// Partition the sorted index array into `n_shards` near-equal-nnz
    /// ranges, snapping each boundary up to the next row boundary of W.
    ///
    /// Row alignment means shard `s` and shard `s+1` write disjoint rows,
    /// so concurrent shards never contend for an output cache line (rows
    /// are ≥ 64 B apart for any serving-scale `cols`).  Cheap: O(n·run)
    /// where `run` is one row's nnz — recomputing per switch is noise next
    /// to the O(nnz) scatter itself.
    pub fn shard(&self, n_shards: usize) -> ShardPlan {
        shard_sorted(&self.idx, self.cols, n_shards)
    }

    // -- scatter hot path -------------------------------------------------

    /// The scatter hot path: `W.flat[idx[i]] += α·delta[i]`.
    ///
    /// Indices are sorted, so writes walk memory monotonically — the
    /// cache-friendly order that makes SHiRA switching ~10× faster than a
    /// dense LoRA fuse at large dims (Fig. 5).
    #[inline]
    pub fn apply(&self, w: &mut Tensor2, alpha: f32) {
        debug_assert_eq!(w.rows, self.rows);
        debug_assert_eq!(w.cols, self.cols);
        unsafe { self.apply_raw(w.data.as_mut_ptr(), alpha, 0, self.nnz()) }
    }

    /// Shard-parallel scatter.  Bit-identical to [`Self::apply`] for any
    /// plan/thread count: indices are unique, so every element of W is
    /// written by exactly one shard with the same single `+=`.
    pub fn apply_parallel(
        &self,
        w: &mut Tensor2,
        alpha: f32,
        pool: &ThreadPool,
        plan: &ShardPlan,
    ) {
        debug_assert_eq!(w.rows, self.rows);
        debug_assert_eq!(w.cols, self.cols);
        debug_assert_eq!(plan.total(), self.nnz());
        let wp = SendPtr::new(w.data.as_mut_ptr());
        let plan = *plan;
        pool.scoped_for(plan.len(), move |s| {
            let (lo, hi) = plan.range(s);
            // SAFETY: shards cover disjoint idx ranges; idx entries are
            // unique and validated < rows*cols at construction.
            unsafe { self.apply_raw(wp.get(), alpha, lo, hi) }
        });
    }

    #[inline]
    unsafe fn apply_raw(&self, w: *mut f32, alpha: f32, lo: usize, hi: usize) {
        kernel::apply_span(
            kernel::active_dispatch(),
            self.idx.as_ptr(),
            F32Src(self.delta.as_ptr()),
            w,
            alpha,
            lo,
            hi,
            Runs::Detect,
        )
    }

    // -- snapshot / restore ----------------------------------------------

    /// Snapshot the base values at this delta's support (for exact revert).
    pub fn snapshot(&self, w: &Tensor2) -> Vec<f32> {
        self.idx.iter().map(|&i| w.data[i as usize]).collect()
    }

    /// Snapshot into a caller-owned buffer (the zero-allocation arena path).
    pub fn snapshot_into(&self, w: &Tensor2, out: &mut [f32]) {
        assert_eq!(out.len(), self.nnz());
        for (o, &i) in out.iter_mut().zip(self.idx.iter()) {
            *o = w.data[i as usize];
        }
    }

    /// Fused snapshot-then-apply over `[lo, hi)` — the switch hot path does
    /// both in one pass over the support (one load feeds both the snapshot
    /// store and the accumulate).
    #[inline]
    pub fn snapshot_apply_range(
        &self,
        w: &mut Tensor2,
        alpha: f32,
        snap: &mut [f32],
        lo: usize,
        hi: usize,
    ) {
        debug_assert_eq!(snap.len(), self.nnz());
        debug_assert!(lo <= hi && hi <= self.nnz());
        unsafe {
            self.snapshot_apply_raw(w.data.as_mut_ptr(), alpha, snap.as_mut_ptr(), lo, hi)
        }
    }

    /// Fused snapshot+apply over the whole support.
    pub fn snapshot_apply(&self, w: &mut Tensor2, alpha: f32, snap: &mut [f32]) {
        self.snapshot_apply_range(w, alpha, snap, 0, self.nnz());
    }

    /// Shard-parallel fused snapshot+apply.
    pub fn snapshot_apply_parallel(
        &self,
        w: &mut Tensor2,
        alpha: f32,
        snap: &mut [f32],
        pool: &ThreadPool,
        plan: &ShardPlan,
    ) {
        assert_eq!(snap.len(), self.nnz());
        debug_assert_eq!(plan.total(), self.nnz());
        let wp = SendPtr::new(w.data.as_mut_ptr());
        let sp = SendPtr::new(snap.as_mut_ptr());
        let plan = *plan;
        pool.scoped_for(plan.len(), move |s| {
            let (lo, hi) = plan.range(s);
            // SAFETY: disjoint idx ranges => disjoint W elements and
            // disjoint snapshot slots.
            unsafe { self.snapshot_apply_raw(wp.get(), alpha, sp.get(), lo, hi) }
        });
    }

    #[inline]
    unsafe fn snapshot_apply_raw(
        &self,
        w: *mut f32,
        alpha: f32,
        snap: *mut f32,
        lo: usize,
        hi: usize,
    ) {
        kernel::snapshot_apply_span(
            kernel::active_dispatch(),
            self.idx.as_ptr(),
            F32Src(self.delta.as_ptr()),
            w,
            snap,
            alpha,
            lo,
            hi,
            Runs::Detect,
        )
    }

    /// Exact revert: write back a snapshot taken before `apply`.
    pub fn restore(&self, w: &mut Tensor2, snapshot: &[f32]) {
        assert_eq!(snapshot.len(), self.nnz());
        unsafe {
            self.restore_raw(w.data.as_mut_ptr(), snapshot.as_ptr(), 0, self.nnz())
        }
    }

    /// Shard-parallel restore.  Bit-identical to [`Self::restore`]: pure
    /// stores of snapshotted values to disjoint locations.
    pub fn restore_parallel(
        &self,
        w: &mut Tensor2,
        snapshot: &[f32],
        pool: &ThreadPool,
        plan: &ShardPlan,
    ) {
        assert_eq!(snapshot.len(), self.nnz());
        debug_assert_eq!(plan.total(), self.nnz());
        let wp = SendPtr::new(w.data.as_mut_ptr());
        let plan = *plan;
        pool.scoped_for(plan.len(), move |s| {
            let (lo, hi) = plan.range(s);
            // SAFETY: disjoint idx ranges => disjoint W elements.
            unsafe { self.restore_raw(wp.get(), snapshot.as_ptr(), lo, hi) }
        });
    }

    #[inline]
    unsafe fn restore_raw(&self, w: *mut f32, snap: *const f32, lo: usize, hi: usize) {
        kernel::restore_span(
            kernel::active_dispatch(),
            self.idx.as_ptr(),
            w,
            snap,
            lo,
            hi,
            Runs::Detect,
        )
    }

    // -- gather -----------------------------------------------------------

    /// Gather current values at the support.
    pub fn gather(&self, w: &Tensor2) -> Vec<f32> {
        self.idx.iter().map(|&i| w.data[i as usize]).collect()
    }

    /// Shard-parallel gather into a caller-owned buffer.
    pub fn gather_parallel(
        &self,
        w: &Tensor2,
        out: &mut [f32],
        pool: &ThreadPool,
        plan: &ShardPlan,
    ) {
        assert_eq!(out.len(), self.nnz());
        debug_assert_eq!(plan.total(), self.nnz());
        let op = SendPtr::new(out.as_mut_ptr());
        let wd = &w.data;
        let plan = *plan;
        pool.scoped_for(plan.len(), move |s| {
            let (lo, hi) = plan.range(s);
            // SAFETY: disjoint out slots per shard; idx validated.
            unsafe {
                kernel::gather_span(
                    kernel::active_dispatch(),
                    self.idx.as_ptr(),
                    wd.as_ptr(),
                    op.get(),
                    lo,
                    hi,
                    Runs::Detect,
                )
            }
        });
    }

    // -- merge ------------------------------------------------------------

    /// Naive multi-adapter fusion (paper Fig. 3b): index-union merge,
    /// summing deltas where supports overlap.
    pub fn merge(&self, other: &SparseDelta) -> SparseDelta {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut idx = Vec::with_capacity(self.nnz() + other.nnz());
        let mut delta = Vec::with_capacity(self.nnz() + other.nnz());
        merge_ranges(
            &self.idx,
            &self.delta,
            &other.idx,
            &other.delta,
            &mut idx,
            &mut delta,
        );
        SparseDelta::new(self.rows, self.cols, idx, delta)
    }

    /// Shard-parallel union-merge, bit-identical to [`Self::merge`].
    ///
    /// Both supports are cut at the same flat-index thresholds (taken from
    /// `self`'s row-aligned plan), each shard's output size is counted in a
    /// first parallel pass, and shards then write disjoint output ranges.
    pub fn merge_parallel(
        &self,
        other: &SparseDelta,
        pool: &ThreadPool,
        n_shards: usize,
    ) -> SparseDelta {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let plan = self.shard(n_shards);
        let n = plan.len();
        if n <= 1 {
            return self.merge(other);
        }
        // Flat-index thresholds at shard starts; both arrays are cut there.
        let numel = self.numel() as u64;
        let mut thresh = [0u64; MAX_SHARDS + 1];
        thresh[n] = numel;
        for s in 1..n {
            let b = plan.bounds[s];
            thresh[s] = if b < self.nnz() {
                self.idx[b] as u64
            } else {
                numel
            };
        }
        let mut ob = [0usize; MAX_SHARDS + 1];
        ob[n] = other.nnz();
        for s in 1..n {
            ob[s] = other.idx.partition_point(|&i| (i as u64) < thresh[s]);
        }

        // Pass 1: per-shard union sizes (disjoint count slots).
        let mut counts = [0usize; MAX_SHARDS];
        let cp = SendPtr::new(counts.as_mut_ptr());
        pool.scoped_for(n, |s| {
            let (alo, ahi) = plan.range(s);
            let c = merge_count(&self.idx[alo..ahi], &other.idx[ob[s]..ob[s + 1]]);
            // SAFETY: one writer per slot.
            unsafe { *cp.get().add(s) = c }
        });
        let mut offs = [0usize; MAX_SHARDS + 1];
        for s in 0..n {
            offs[s + 1] = offs[s] + counts[s];
        }
        let total = offs[n];

        // Pass 2: write each shard's merged run at its offset.
        let mut out_idx = vec![0u32; total];
        let mut out_delta = vec![0f32; total];
        let oi = SendPtr::new(out_idx.as_mut_ptr());
        let od = SendPtr::new(out_delta.as_mut_ptr());
        pool.scoped_for(n, |s| {
            let (alo, ahi) = plan.range(s);
            // SAFETY: output ranges [offs[s], offs[s+1]) are disjoint.
            unsafe {
                merge_write(
                    &self.idx[alo..ahi],
                    &self.delta[alo..ahi],
                    &other.idx[ob[s]..ob[s + 1]],
                    &other.delta[ob[s]..ob[s + 1]],
                    oi.get().add(offs[s]),
                    od.get().add(offs[s]),
                );
            }
        });
        SparseDelta::new(self.rows, self.cols, out_idx, out_delta)
    }

    /// Scale the delta (the paper's α baked in permanently).
    pub fn scaled(&self, alpha: f32) -> SparseDelta {
        SparseDelta {
            rows: self.rows,
            cols: self.cols,
            idx: self.idx.clone(),
            delta: self.delta.iter().map(|d| d * alpha).collect(),
        }
    }

    /// |support(self) ∩ support(other)| — the collision count that drives
    /// multi-adapter interference (paper §3.2).
    pub fn overlap(&self, other: &SparseDelta) -> usize {
        let (mut a, mut b, mut n) = (0usize, 0usize, 0usize);
        while a < self.nnz() && b < other.nnz() {
            match self.idx[a].cmp(&other.idx[b]) {
                std::cmp::Ordering::Less => a += 1,
                std::cmp::Ordering::Greater => b += 1,
                std::cmp::Ordering::Equal => {
                    n += 1;
                    a += 1;
                    b += 1;
                }
            }
        }
        n
    }

    /// Number of nonzero entries of `selfᵀ · other` (both viewed as dense
    /// n×m matrices with these sparse supports).  An entry (c1, c2) of the
    /// product is nonzero only if some row r has self[r,c1] ≠ 0 and
    /// other[r,c2] ≠ 0 — the orthogonality diagnostic of paper §3.2.
    /// Returns (nnz, total = m²).
    ///
    /// Sorted row-major indices mean each row's columns are a contiguous
    /// run, so both supports are walked with two cursors — no per-row
    /// `Vec<Vec<u32>>` grouping pass and no allocation beyond the dedup
    /// set itself.
    pub fn ata_nnz(&self, other: &SparseDelta) -> (usize, usize) {
        use std::collections::HashSet;
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let cols = self.cols;
        let mut pairs: HashSet<u64> = HashSet::new();
        let (mut a, mut b) = (0usize, 0usize);
        while a < self.nnz() && b < other.nnz() {
            let ra = self.idx[a] as usize / cols;
            let rb = other.idx[b] as usize / cols;
            if ra < rb {
                a = row_run_end(&self.idx, a, cols);
            } else if rb < ra {
                b = row_run_end(&other.idx, b, cols);
            } else {
                let a_end = row_run_end(&self.idx, a, cols);
                let b_end = row_run_end(&other.idx, b, cols);
                for &i1 in &self.idx[a..a_end] {
                    let c1 = (i1 as usize % cols) as u64;
                    for &i2 in &other.idx[b..b_end] {
                        let c2 = (i2 as usize % cols) as u64;
                        pairs.insert(c1 << 32 | c2);
                    }
                }
                a = a_end;
                b = b_end;
            }
        }
        (pairs.len(), cols * cols)
    }

    /// Densify (tests / analysis only).
    pub fn to_dense(&self) -> Tensor2 {
        let mut t = Tensor2::zeros(self.rows, self.cols);
        for (&i, &d) in self.idx.iter().zip(self.delta.iter()) {
            t.data[i as usize] = d;
        }
        t
    }
}

/// f16-resident sparse delta: the same sorted support as [`SparseDelta`]
/// with values held as raw IEEE 754 binary16 bits — 2 bytes per entry
/// instead of 4, halving resident delta bytes and apply-time cache
/// traffic (the store's f16-resident mode, DESIGN.md §15).  Values are
/// widened to f32 lane-wise inside the kernel on apply; widening is
/// exact, so serving an f16-resident adapter is bit-identical to serving
/// the f32 decode of the same `v2-f16` file.
///
/// # Examples
///
/// ```
/// use shira::adapter::sparse::{SparseDelta, SparseDeltaF16};
///
/// let d = SparseDelta::new(2, 4, vec![1, 6], vec![0.5, -2.0]);
/// let q = SparseDeltaF16::from_f32(&d); // lossy narrowing (RNE)
/// assert_eq!(q.to_f32(), d); // 0.5 and -2.0 are f16-representable
/// assert_eq!(q.nbytes(), 12); // 6 B/entry vs SparseDelta's 8
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct SparseDeltaF16 {
    /// Rows of the target tensor.
    pub rows: usize,
    /// Columns of the target tensor.
    pub cols: usize,
    /// Sorted, unique flat indices (row-major).
    pub idx: Vec<u32>,
    /// Raw binary16 bits of delta[i].
    pub bits: Vec<u16>,
}

impl SparseDeltaF16 {
    /// Build from sorted unique flat indices and raw binary16 values.
    pub fn new(rows: usize, cols: usize, idx: Vec<u32>, bits: Vec<u16>) -> Self {
        assert_eq!(idx.len(), bits.len());
        debug_assert!(idx.windows(2).all(|w| w[0] < w[1]), "indices sorted+unique");
        debug_assert!(idx.iter().all(|&i| (i as usize) < rows * cols));
        SparseDeltaF16 {
            rows,
            cols,
            idx,
            bits,
        }
    }

    /// Number of nonzero entries.
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Elements of the target tensor (rows × cols).
    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    /// Resident bytes (idx u32 + bits u16 — 6 B/entry vs f32's 8).
    pub fn nbytes(&self) -> usize {
        self.nnz() * 6
    }

    /// Row-aligned shard partition (see [`SparseDelta::shard`]).
    pub fn shard(&self, n_shards: usize) -> ShardPlan {
        shard_sorted(&self.idx, self.cols, n_shards)
    }

    /// Exact widening to an f32-resident delta (every binary16 value is
    /// representable in f32, so this is lossless and `to_f32().apply` is
    /// bit-identical to the kernel's lane-wise dequantized apply).
    pub fn to_f32(&self) -> SparseDelta {
        let delta = self
            .bits
            .iter()
            .map(|&b| crate::adapter::io::f16_bits_to_f32(b))
            .collect();
        SparseDelta::new(self.rows, self.cols, self.idx.clone(), delta)
    }

    /// Lossy narrowing (round-to-nearest-even) — the quantization step.
    /// `from_f32(d).to_f32() == d` only when every value of `d` is
    /// f16-representable (always true for values decoded from `v2-f16`).
    pub fn from_f32(d: &SparseDelta) -> SparseDeltaF16 {
        let bits = d
            .delta
            .iter()
            .map(|&v| crate::adapter::io::f32_to_f16_bits(v))
            .collect();
        SparseDeltaF16::new(d.rows, d.cols, d.idx.clone(), bits)
    }

    /// Serial fused snapshot+apply (the reference twin of the switch
    /// engine's f16 task path).
    pub fn snapshot_apply(&self, w: &mut Tensor2, alpha: f32, snap: &mut [f32]) {
        assert_eq!(snap.len(), self.nnz());
        debug_assert_eq!((w.rows, w.cols), (self.rows, self.cols));
        unsafe {
            kernel::snapshot_apply_span(
                kernel::active_dispatch(),
                self.idx.as_ptr(),
                F16Src(self.bits.as_ptr()),
                w.data.as_mut_ptr(),
                snap.as_mut_ptr(),
                alpha,
                0,
                self.nnz(),
                Runs::Detect,
            )
        }
    }

    /// Exact revert: write back a snapshot taken before apply (bit-wise
    /// identical to [`SparseDelta::restore`] — only indices are read).
    pub fn restore(&self, w: &mut Tensor2, snapshot: &[f32]) {
        assert_eq!(snapshot.len(), self.nnz());
        unsafe {
            kernel::restore_span(
                kernel::active_dispatch(),
                self.idx.as_ptr(),
                w.data.as_mut_ptr(),
                snapshot.as_ptr(),
                0,
                self.nnz(),
                Runs::Detect,
            )
        }
    }
}

/// Row-aligned partition of *any* sorted unique flat-index slice into at
/// most `n_shards` contiguous near-equal ranges (the generalization of
/// [`SparseDelta::shard`], shared with the fusion engine's merged-support
/// refresh and the [`TransitionPlan`] union walk).
pub(crate) fn shard_sorted(idx: &[u32], cols: usize, n_shards: usize) -> ShardPlan {
    let n = n_shards.clamp(1, MAX_SHARDS);
    let nnz = idx.len();
    let mut bounds = [0usize; MAX_SHARDS + 1];
    let mut prev = 0usize;
    for s in 1..n {
        let mut t = (nnz * s / n).max(prev);
        if t > 0 && t < nnz && cols > 0 {
            let row = idx[t - 1] as usize / cols;
            while t < nnz && idx[t] as usize / cols == row {
                t += 1;
            }
        }
        bounds[s] = t;
        prev = t;
    }
    bounds[n] = nnz;
    ShardPlan {
        n_shards: n,
        bounds,
    }
}

/// Sentinel in [`TransitionPlan`] position arrays: the union slot has no
/// entry on that side.
pub(crate) const NONE_POS: u32 = u32::MAX;

/// Precomputed direct A→B transition layout for one target tensor: the
/// merged union of A's and B's sorted supports with each union slot
/// classified by which sides carry it.
///
/// Slot classification (the three cases of the transition kernel,
/// [`kernel::transition_span`]):
///
/// * **A-only** (`a_pos` set, `b_pos` absent): restore A's snapshot value —
///   exactly what `revert` would have written, and B leaves it alone.
/// * **B-only** (`b_pos` set, `a_pos` absent): the resident value IS the
///   base (A never touched it); snapshot it for B's future revert and
///   write `base + α·Δ_B`.
/// * **overlap** (both set): the base is A's *snapshot* value, not the
///   resident one — capture it as B's snapshot and write
///   `snap_A + α·Δ_B`, skipping the intermediate restore entirely.
///
/// One pass over the union therefore lands the weights (and B's snapshot
/// buffer) in exactly the state a `revert` followed by a fresh
/// snapshot+apply of B would have produced, bit for bit — but each union
/// slot is touched once instead of up to twice, and the whole transition
/// dispatches as one parallel wave over the embedded row-aligned
/// [`ShardPlan`].
///
/// # Examples
///
/// ```
/// use shira::adapter::sparse::{SparseDelta, TransitionPlan};
/// use shira::model::tensor::Tensor2;
///
/// let a = SparseDelta::new(2, 4, vec![1, 3], vec![10.0, 20.0]);
/// let b = SparseDelta::new(2, 4, vec![3, 6], vec![5.0, 7.0]);
/// let tp = TransitionPlan::build(&a, &b, 1);
/// assert_eq!(tp.union_nnz(), 3); // {1, 3, 6}
/// assert_eq!(tp.overlap(), 1); // slot 3
///
/// let mut w = Tensor2::zeros(2, 4);
/// let snap_a = a.snapshot(&w); // base values on A's support
/// a.apply(&mut w, 1.0);
/// let mut snap_b = vec![0.0; b.nnz()];
/// tp.transition(&mut w, &snap_a, &mut snap_b, &b, 1.0);
/// // Identical to revert(A) + snapshot + apply(B):
/// assert_eq!(w.data[1], 0.0); // A-only slot restored
/// assert_eq!(w.data[3], 5.0); // overlap: base (0) + B's delta
/// assert_eq!(w.data[6], 7.0); // B-only slot applied
/// assert_eq!(snap_b, vec![0.0, 0.0]); // B's revert snapshot is base
/// ```
#[derive(Clone, Debug)]
pub struct TransitionPlan {
    rows: usize,
    cols: usize,
    /// Sorted unique union of A's and B's supports (flat indices).
    union_idx: Vec<u32>,
    /// Per union slot: position in A's support/snapshot, or `NONE_POS`.
    a_pos: Vec<u32>,
    /// Per union slot: position in B's support/snapshot, or `NONE_POS`.
    b_pos: Vec<u32>,
    a_nnz: usize,
    b_nnz: usize,
    overlap: usize,
    /// Row-aligned shards over the union walk (one-wave dispatch).
    shards: ShardPlan,
    /// Run cuts over the union walk, aligned to the shard boundaries
    /// (lets the SIMD kernel sweep consecutive union slots).
    runs: RunPlan,
}

impl TransitionPlan {
    /// Merge A's and B's sorted supports into a classified union plan with
    /// a row-aligned [`ShardPlan`] sized for `n_shards`-wide dispatch.
    /// Both deltas must target the same tensor shape.
    pub fn build(a: &SparseDelta, b: &SparseDelta, n_shards: usize) -> TransitionPlan {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols), "transition shape");
        let cap = a.nnz() + b.nnz();
        let mut union_idx = Vec::with_capacity(cap);
        let mut a_pos = Vec::with_capacity(cap);
        let mut b_pos = Vec::with_capacity(cap);
        let mut overlap = 0usize;
        let (mut i, mut j) = (0usize, 0usize);
        while i < a.nnz() || j < b.nnz() {
            let ia = a.idx.get(i).copied().unwrap_or(u32::MAX);
            let ib = b.idx.get(j).copied().unwrap_or(u32::MAX);
            if ia < ib {
                union_idx.push(ia);
                a_pos.push(i as u32);
                b_pos.push(NONE_POS);
                i += 1;
            } else if ib < ia {
                union_idx.push(ib);
                a_pos.push(NONE_POS);
                b_pos.push(j as u32);
                j += 1;
            } else {
                union_idx.push(ia);
                a_pos.push(i as u32);
                b_pos.push(j as u32);
                overlap += 1;
                i += 1;
                j += 1;
            }
        }
        // Capacity was the no-overlap worst case; release the overlap's
        // worth so `nbytes` (the plan-cache accounting unit) is the real
        // heap footprint.
        union_idx.shrink_to_fit();
        a_pos.shrink_to_fit();
        b_pos.shrink_to_fit();
        let shards = shard_sorted(&union_idx, a.cols, n_shards);
        let runs = RunPlan::build(&union_idx, &shards);
        TransitionPlan {
            rows: a.rows,
            cols: a.cols,
            union_idx,
            a_pos,
            b_pos,
            a_nnz: a.nnz(),
            b_nnz: b.nnz(),
            overlap,
            shards,
            runs,
        }
    }

    /// Rows of the target tensor this plan transitions.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Columns of the target tensor this plan transitions.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// |support(A) ∪ support(B)| — the slots one transition touches.
    pub fn union_nnz(&self) -> usize {
        self.union_idx.len()
    }

    /// |support(A) ∩ support(B)| — slots that skip the restore entirely.
    pub fn overlap(&self) -> usize {
        self.overlap
    }

    /// nnz of the A (outgoing) side the plan was built for.
    pub fn a_nnz(&self) -> usize {
        self.a_nnz
    }

    /// nnz of the B (incoming) side the plan was built for.
    pub fn b_nnz(&self) -> usize {
        self.b_nnz
    }

    /// The embedded row-aligned shard plan over the union walk.
    pub fn shards(&self) -> &ShardPlan {
        &self.shards
    }

    /// The embedded run cuts over the union walk.
    pub(crate) fn runs(&self) -> &RunPlan {
        &self.runs
    }

    /// Heap bytes held by the plan (the plan-cache accounting unit).
    pub fn nbytes(&self) -> usize {
        self.union_idx.len() * 12 + self.runs.nbytes() + std::mem::size_of::<TransitionPlan>()
    }

    /// Raw array pointers for the engine's flat task list:
    /// `(union_idx, a_pos, b_pos)`.
    pub(crate) fn raw_parts(&self) -> (*const u32, *const u32, *const u32) {
        (
            self.union_idx.as_ptr(),
            self.a_pos.as_ptr(),
            self.b_pos.as_ptr(),
        )
    }

    /// One-pass direct transition over the whole union (serial).
    ///
    /// `snap_a` is the base snapshot taken when A was applied; `snap_b`
    /// (length `b.nnz()`) receives the base snapshot for B's future
    /// revert; `b` is the incoming delta, applied at `alpha`.  The result
    /// is bit-identical to `a.restore(w, snap_a)` followed by
    /// `b.snapshot_apply(w, alpha, snap_b)`.
    pub fn transition(
        &self,
        w: &mut Tensor2,
        snap_a: &[f32],
        snap_b: &mut [f32],
        b: &SparseDelta,
        alpha: f32,
    ) {
        assert_eq!((w.rows, w.cols), (self.rows, self.cols));
        assert_eq!(snap_a.len(), self.a_nnz);
        assert_eq!(snap_b.len(), self.b_nnz);
        assert_eq!(b.nnz(), self.b_nnz);
        let un = self.union_idx.len();
        let (rp, rn) = self.runs.span(0, un);
        unsafe {
            kernel::transition_span(
                kernel::active_dispatch(),
                self.union_idx.as_ptr(),
                self.a_pos.as_ptr(),
                self.b_pos.as_ptr(),
                F32Src(b.delta.as_ptr()),
                w.data.as_mut_ptr(),
                snap_a.as_ptr(),
                snap_b.as_mut_ptr(),
                alpha,
                0,
                un,
                Runs::Cuts { ptr: rp, len: rn },
            )
        }
    }

    /// Shard-parallel one-pass transition — one `scoped_for` wave over the
    /// embedded row-aligned shards, bit-identical to [`Self::transition`]
    /// (disjoint union ranges ⇒ disjoint W slots and disjoint `snap_b`
    /// slots; `snap_a` is read-only).
    pub fn transition_parallel(
        &self,
        w: &mut Tensor2,
        snap_a: &[f32],
        snap_b: &mut [f32],
        b: &SparseDelta,
        alpha: f32,
        pool: &ThreadPool,
    ) {
        assert_eq!((w.rows, w.cols), (self.rows, self.cols));
        assert_eq!(snap_a.len(), self.a_nnz);
        assert_eq!(snap_b.len(), self.b_nnz);
        assert_eq!(b.nnz(), self.b_nnz);
        let wp = SendPtr::new(w.data.as_mut_ptr());
        let sb = SendPtr::new(snap_b.as_mut_ptr());
        let plan = self.shards;
        let dispatch = kernel::active_dispatch();
        pool.scoped_for(plan.len(), move |s| {
            let (lo, hi) = plan.range(s);
            let (rp, rn) = self.runs.span(lo, hi);
            // SAFETY: shards cover disjoint union ranges; union indices
            // are unique, so W and snap_b slots are written exactly once.
            unsafe {
                kernel::transition_span(
                    dispatch,
                    self.union_idx.as_ptr(),
                    self.a_pos.as_ptr(),
                    self.b_pos.as_ptr(),
                    F32Src(b.delta.as_ptr()),
                    wp.get(),
                    snap_a.as_ptr(),
                    sb.get(),
                    alpha,
                    lo,
                    hi,
                    Runs::Cuts { ptr: rp, len: rn },
                )
            }
        });
    }
}

/// End of the run of entries sharing `idx[start]`'s row.
#[inline]
fn row_run_end(idx: &[u32], start: usize, cols: usize) -> usize {
    let row = idx[start] as usize / cols;
    let mut e = start + 1;
    while e < idx.len() && idx[e] as usize / cols == row {
        e += 1;
    }
    e
}

/// Two-pointer union size of two sorted unique index slices.
fn merge_count(a: &[u32], b: &[u32]) -> usize {
    let (mut i, mut j, mut c) = (0usize, 0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                i += 1;
                j += 1;
            }
        }
        c += 1;
    }
    c + (a.len() - i) + (b.len() - j)
}

/// Union-merge into Vecs (serial path).
fn merge_ranges(
    a_idx: &[u32],
    a_val: &[f32],
    b_idx: &[u32],
    b_val: &[f32],
    out_idx: &mut Vec<u32>,
    out_val: &mut Vec<f32>,
) {
    let (mut a, mut b) = (0usize, 0usize);
    while a < a_idx.len() || b < b_idx.len() {
        let ia = a_idx.get(a).copied().unwrap_or(u32::MAX);
        let ib = b_idx.get(b).copied().unwrap_or(u32::MAX);
        if ia < ib {
            out_idx.push(ia);
            out_val.push(a_val[a]);
            a += 1;
        } else if ib < ia {
            out_idx.push(ib);
            out_val.push(b_val[b]);
            b += 1;
        } else {
            out_idx.push(ia);
            out_val.push(a_val[a] + b_val[b]);
            a += 1;
            b += 1;
        }
    }
}

/// Union-merge into raw output cursors (parallel pass 2).
///
/// # Safety
/// `oi`/`od` must have room for `merge_count(a_idx, b_idx)` entries and be
/// written by exactly one shard.
unsafe fn merge_write(
    a_idx: &[u32],
    a_val: &[f32],
    b_idx: &[u32],
    b_val: &[f32],
    mut oi: *mut u32,
    mut od: *mut f32,
) {
    let (mut a, mut b) = (0usize, 0usize);
    while a < a_idx.len() || b < b_idx.len() {
        let ia = a_idx.get(a).copied().unwrap_or(u32::MAX);
        let ib = b_idx.get(b).copied().unwrap_or(u32::MAX);
        if ia < ib {
            *oi = ia;
            *od = a_val[a];
            a += 1;
        } else if ib < ia {
            *oi = ib;
            *od = b_val[b];
            b += 1;
        } else {
            *oi = ia;
            *od = a_val[a] + b_val[b];
            a += 1;
            b += 1;
        }
        oi = oi.add(1);
        od = od.add(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;
    use crate::util::rng::Rng;

    fn random_delta(rng: &mut Rng, rows: usize, cols: usize, k: usize) -> SparseDelta {
        let idx = rng.sample_indices(rows * cols, k);
        let mut delta = vec![0.0; k];
        rng.fill_normal(&mut delta, 0.0, 1.0);
        SparseDelta::new(rows, cols, idx, delta)
    }

    fn random_w(rng: &mut Rng, rows: usize, cols: usize) -> Tensor2 {
        let mut t = Tensor2::zeros(rows, cols);
        rng.fill_normal(&mut t.data, 0.0, 1.0);
        t
    }

    #[test]
    fn apply_changes_exactly_support() {
        let mut rng = Rng::new(1);
        let w0 = random_w(&mut rng, 16, 16);
        let d = random_delta(&mut rng, 16, 16, 10);
        let mut w = w0.clone();
        d.apply(&mut w, 1.0);
        let mut changed = 0;
        for i in 0..w.numel() {
            if w.data[i] != w0.data[i] {
                changed += 1;
                assert!(d.idx.contains(&(i as u32)));
            }
        }
        assert_eq!(changed, 10);
    }

    #[test]
    fn apply_alpha_scales() {
        let mut rng = Rng::new(2);
        let w0 = random_w(&mut rng, 8, 8);
        let d = random_delta(&mut rng, 8, 8, 5);
        let mut w_half = w0.clone();
        d.apply(&mut w_half, 0.5);
        for (j, &i) in d.idx.iter().enumerate() {
            let want = w0.data[i as usize] + 0.5 * d.delta[j];
            assert_eq!(w_half.data[i as usize], want);
        }
    }

    #[test]
    fn snapshot_restore_is_bit_exact() {
        let mut rng = Rng::new(3);
        let w0 = random_w(&mut rng, 32, 32);
        let d = random_delta(&mut rng, 32, 32, 64);
        let mut w = w0.clone();
        let snap = d.snapshot(&w);
        d.apply(&mut w, 1.7);
        assert!(w.max_abs_diff(&w0) > 0.0);
        d.restore(&mut w, &snap);
        assert_eq!(w.data, w0.data); // exact, not approx — the SHiRA claim
    }

    #[test]
    fn snapshot_into_matches_snapshot() {
        let mut rng = Rng::new(31);
        let w = random_w(&mut rng, 16, 16);
        let d = random_delta(&mut rng, 16, 16, 20);
        let mut buf = vec![0.0f32; 20];
        d.snapshot_into(&w, &mut buf);
        assert_eq!(buf, d.snapshot(&w));
    }

    #[test]
    fn fused_snapshot_apply_matches_two_pass() {
        let mut rng = Rng::new(32);
        let w0 = random_w(&mut rng, 24, 24);
        let d = random_delta(&mut rng, 24, 24, 48);
        let mut w1 = w0.clone();
        let snap1 = d.snapshot(&w1);
        d.apply(&mut w1, 0.8);
        let mut w2 = w0.clone();
        let mut snap2 = vec![0.0f32; d.nnz()];
        d.snapshot_apply(&mut w2, 0.8, &mut snap2);
        assert_eq!(w1.data, w2.data);
        assert_eq!(snap1, snap2);
    }

    #[test]
    fn from_diff_roundtrip() {
        let mut rng = Rng::new(4);
        let base = random_w(&mut rng, 8, 12);
        let idx = rng.sample_indices(96, 9);
        let tuned: Vec<f32> = idx.iter().map(|&i| base.data[i as usize] + 2.0).collect();
        let d = SparseDelta::from_diff(&base, &tuned, idx.clone());
        let mut w = base.clone();
        d.apply(&mut w, 1.0);
        for (&i, &t) in idx.iter().zip(tuned.iter()) {
            assert!((w.data[i as usize] - t).abs() < 1e-6);
        }
    }

    #[test]
    fn merge_unions_and_sums() {
        let a = SparseDelta::new(2, 4, vec![0, 3, 5], vec![1.0, 2.0, 3.0]);
        let b = SparseDelta::new(2, 4, vec![3, 6], vec![10.0, 20.0]);
        let m = a.merge(&b);
        assert_eq!(m.idx, vec![0, 3, 5, 6]);
        assert_eq!(m.delta, vec![1.0, 12.0, 3.0, 20.0]);
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let a = SparseDelta::new(2, 4, vec![1, 2], vec![1.0, 2.0]);
        let e = SparseDelta::new(2, 4, vec![], vec![]);
        assert_eq!(a.merge(&e), a);
        assert_eq!(e.merge(&a), a);
    }

    #[test]
    fn overlap_counts_shared_support() {
        let a = SparseDelta::new(4, 4, vec![0, 1, 8], vec![1.0; 3]);
        let b = SparseDelta::new(4, 4, vec![1, 8, 9], vec![1.0; 3]);
        assert_eq!(a.overlap(&b), 2);
        assert_eq!(b.overlap(&a), 2);
        assert_eq!(a.overlap(&a), 3);
    }

    #[test]
    fn ata_sparse_vs_dense_shapes() {
        // Two 1%-sparse adapters: product should be overwhelmingly zero.
        let mut rng = Rng::new(5);
        let n = 64;
        let k = (n * n) / 100;
        let a = random_delta(&mut rng, n, n, k);
        let b = random_delta(&mut rng, n, n, k);
        let (nnz, total) = a.ata_nnz(&b);
        assert!(total == n * n);
        assert!(
            (nnz as f64) < 0.05 * total as f64,
            "sparse product unexpectedly dense: {nnz}/{total}"
        );
    }

    #[test]
    fn ata_nnz_exact_small() {
        // a has (r0,c0)=(0,1); b has (0,2),(1,3): product nonzero only (1,2).
        let a = SparseDelta::new(2, 4, vec![1], vec![1.0]);
        let b = SparseDelta::new(2, 4, vec![2, 7], vec![1.0, 1.0]);
        let (nnz, total) = a.ata_nnz(&b);
        assert_eq!(nnz, 1);
        assert_eq!(total, 16);
    }

    #[test]
    fn ata_nnz_matches_dense_reference() {
        // Cross-check the run-based walk against a brute-force dense count.
        let mut rng = Rng::new(51);
        for _ in 0..10 {
            let (rows, cols) = (4 + rng.below(8), 4 + rng.below(8));
            let total = rows * cols;
            let a = random_delta(&mut rng, rows, cols, 1 + rng.below(total / 2));
            let b = random_delta(&mut rng, rows, cols, 1 + rng.below(total / 2));
            let da = a.to_dense();
            let db = b.to_dense();
            let mut want = 0usize;
            for c1 in 0..cols {
                for c2 in 0..cols {
                    let nz = (0..rows)
                        .any(|r| da.at(r, c1) != 0.0 && db.at(r, c2) != 0.0);
                    if nz {
                        want += 1;
                    }
                }
            }
            let (got, tot) = a.ata_nnz(&b);
            assert_eq!(got, want);
            assert_eq!(tot, cols * cols);
        }
    }

    #[test]
    fn shard_plan_is_row_aligned_partition() {
        let mut rng = Rng::new(52);
        for &(rows, cols, k, n) in
            &[(32usize, 32usize, 200usize, 4usize), (8, 128, 300, 8), (64, 16, 1, 7), (4, 4, 0, 3)]
        {
            let d = random_delta(&mut rng, rows, cols, k);
            let plan = d.shard(n);
            assert_eq!(plan.total(), d.nnz());
            let mut covered = 0usize;
            for s in 0..plan.len() {
                let (lo, hi) = plan.range(s);
                assert!(lo <= hi);
                assert_eq!(lo, covered);
                covered = hi;
                if s > 0 && lo > 0 && lo < d.nnz() {
                    let prev_row = d.idx[lo - 1] as usize / cols;
                    let this_row = d.idx[lo] as usize / cols;
                    assert!(prev_row < this_row, "boundary splits a row");
                }
            }
            assert_eq!(covered, d.nnz());
        }
    }

    #[test]
    fn parallel_apply_restore_bit_identical_for_any_thread_count() {
        // The tentpole invariant: shard-parallel scatter/restore produce
        // bytes equal to the serial path for thread counts 1, 2, N.
        let mut rng = Rng::new(53);
        let d = random_delta(&mut rng, 64, 64, 700);
        let w0 = random_w(&mut rng, 64, 64);
        let mut w_serial = w0.clone();
        d.apply(&mut w_serial, 1.3);
        for threads in [1usize, 2, 7] {
            let pool = ThreadPool::new(threads);
            let plan = d.shard(threads * 2);
            let mut w = w0.clone();
            let mut snap = vec![0.0f32; d.nnz()];
            d.snapshot_apply_parallel(&mut w, 1.3, &mut snap, &pool, &plan);
            assert_eq!(w.data, w_serial.data, "apply threads={threads}");
            assert_eq!(snap, d.snapshot(&w0), "snapshot threads={threads}");
            d.restore_parallel(&mut w, &snap, &pool, &plan);
            assert_eq!(w.data, w0.data, "restore threads={threads}");
            let mut w2 = w0.clone();
            d.apply_parallel(&mut w2, 1.3, &pool, &plan);
            assert_eq!(w2.data, w_serial.data, "apply_parallel threads={threads}");
        }
    }

    #[test]
    fn parallel_gather_matches_serial() {
        let mut rng = Rng::new(54);
        let d = random_delta(&mut rng, 32, 32, 100);
        let w = random_w(&mut rng, 32, 32);
        let pool = ThreadPool::new(3);
        let plan = d.shard(5);
        let mut out = vec![0.0f32; d.nnz()];
        d.gather_parallel(&w, &mut out, &pool, &plan);
        assert_eq!(out, d.gather(&w));
    }

    #[test]
    fn prop_parallel_merge_bit_identical() {
        let pool = ThreadPool::new(4);
        pt::forall(
            55,
            30,
            |r| {
                let rows = 2 + r.below(16);
                let cols = 2 + r.below(16);
                let total = rows * cols;
                let ka = 1 + r.below(total);
                let kb = 1 + r.below(total);
                (r.next_u64(), rows, cols, ka, kb)
            },
            |&(seed, rows, cols, ka, kb)| {
                let mut rng = Rng::new(seed);
                let a = random_delta(&mut rng, rows, cols, ka);
                let b = random_delta(&mut rng, rows, cols, kb);
                let serial = a.merge(&b);
                [1usize, 2, 5, 16].iter().all(|&n| {
                    let par = a.merge_parallel(&b, &pool, n);
                    par.idx == serial.idx && par.delta == serial.delta
                })
            },
        );
    }

    #[test]
    fn prop_parallel_apply_restore_bit_identical() {
        let pool = ThreadPool::new(4);
        pt::forall(
            56,
            25,
            |r| {
                let rows = 2 + r.below(24);
                let cols = 2 + r.below(24);
                let total = rows * cols;
                let k = 1 + r.below(total);
                let shards = 1 + r.below(12);
                let alpha = -2.0 + 4.0 * r.uniform_f32();
                (r.next_u64(), rows, cols, k, shards, alpha)
            },
            |&(seed, rows, cols, k, shards, alpha)| {
                let mut rng = Rng::new(seed);
                let d = random_delta(&mut rng, rows, cols, k);
                let w0 = random_w(&mut rng, rows, cols);
                let plan = d.shard(shards);
                let mut ws = w0.clone();
                d.apply(&mut ws, alpha);
                let mut wp = w0.clone();
                let mut snap = vec![0.0f32; d.nnz()];
                d.snapshot_apply_parallel(&mut wp, alpha, &mut snap, &pool, &plan);
                if wp.data != ws.data {
                    return false;
                }
                d.restore_parallel(&mut wp, &snap, &pool, &plan);
                wp.data == w0.data
            },
        );
    }

    #[test]
    fn prop_merge_commutes_on_disjoint_supports() {
        pt::forall(
            7,
            40,
            |r| {
                let rows = 4 + r.below(8);
                let cols = 4 + r.below(8);
                let total = rows * cols;
                let k1 = 1 + r.below(total / 2);
                let extra = r.below(total / 2);
                let all = r.sample_indices(total, (k1 + 1 + extra).min(total));
                let split = k1.min(all.len() - 1).max(1);
                (rows, cols, all, split)
            },
            |(rows, cols, all, split)| {
                let (i1, i2) = all.split_at(*split);
                let d1 = SparseDelta::new(
                    *rows,
                    *cols,
                    i1.to_vec(),
                    i1.iter().map(|&i| i as f32).collect(),
                );
                let mut i2s = i2.to_vec();
                i2s.sort_unstable();
                let d2 = SparseDelta::new(
                    *rows,
                    *cols,
                    i2s.clone(),
                    i2s.iter().map(|&i| -(i as f32)).collect(),
                );
                d1.merge(&d2) == d2.merge(&d1)
            },
        );
    }

    #[test]
    fn prop_apply_revert_exact_for_any_alpha_sequence() {
        // Serving invariant (DESIGN.md §7): any interleaving of
        // apply/revert pairs leaves the base bit-identical.
        pt::forall(
            8,
            30,
            |r| {
                let alphas: Vec<f32> = (0..1 + r.below(4))
                    .map(|_| -2.0 + 4.0 * r.uniform_f32())
                    .collect();
                (r.next_u64(), alphas)
            },
            |(seed, alphas)| {
                let mut rng = Rng::new(*seed);
                let w0 = random_w(&mut rng, 16, 16);
                let mut w = w0.clone();
                for &a in alphas {
                    let d = random_delta(&mut rng, 16, 16, 8);
                    let snap = d.snapshot(&w);
                    d.apply(&mut w, a);
                    d.restore(&mut w, &snap);
                }
                w.data == w0.data
            },
        );
    }

    #[test]
    fn transition_plan_classifies_slots() {
        let a = SparseDelta::new(2, 4, vec![0, 3, 5], vec![1.0, 2.0, 3.0]);
        let b = SparseDelta::new(2, 4, vec![3, 6], vec![10.0, 20.0]);
        let tp = TransitionPlan::build(&a, &b, 2);
        assert_eq!(tp.union_nnz(), 4); // {0, 3, 5, 6}
        assert_eq!(tp.overlap(), 1); // slot 3
        assert_eq!((tp.a_nnz(), tp.b_nnz()), (3, 2));
        assert_eq!(tp.shards().total(), 4);
        // classification arrays line up with the union walk
        assert_eq!(tp.union_idx, vec![0, 3, 5, 6]);
        assert_eq!(tp.a_pos, vec![0, 1, 2, NONE_POS]);
        assert_eq!(tp.b_pos, vec![NONE_POS, 0, NONE_POS, 1]);
    }

    /// Reference: the two-pass path the transition must be bit-identical
    /// to.  Returns (weights after, B's snapshot).
    fn revert_then_apply(
        w0: &Tensor2,
        a: &SparseDelta,
        b: &SparseDelta,
        alpha_a: f32,
        alpha_b: f32,
    ) -> (Tensor2, Vec<f32>) {
        let mut w = w0.clone();
        let snap_a = a.snapshot(&w);
        a.apply(&mut w, alpha_a);
        a.restore(&mut w, &snap_a);
        let mut snap_b = vec![0.0f32; b.nnz()];
        b.snapshot_apply(&mut w, alpha_b, &mut snap_b);
        (w, snap_b)
    }

    #[test]
    fn transition_matches_revert_apply_serial_and_parallel() {
        let mut rng = Rng::new(60);
        let pool = ThreadPool::new(4);
        let w0 = random_w(&mut rng, 32, 32);
        let a = random_delta(&mut rng, 32, 32, 120);
        let b = random_delta(&mut rng, 32, 32, 90);
        let (want_w, want_snap) = revert_then_apply(&w0, &a, &b, 0.7, 1.3);
        for shards in [1usize, 3, 8] {
            let tp = TransitionPlan::build(&a, &b, shards);
            // serial
            let mut w = w0.clone();
            let snap_a = a.snapshot(&w);
            a.apply(&mut w, 0.7);
            let mut snap_b = vec![0.0f32; b.nnz()];
            tp.transition(&mut w, &snap_a, &mut snap_b, &b, 1.3);
            assert_eq!(w.data, want_w.data, "serial shards={shards}");
            assert_eq!(snap_b, want_snap, "serial snap shards={shards}");
            // parallel
            let mut w = w0.clone();
            a.apply(&mut w, 0.7);
            let mut snap_b = vec![0.0f32; b.nnz()];
            tp.transition_parallel(&mut w, &snap_a, &mut snap_b, &b, 1.3, &pool);
            assert_eq!(w.data, want_w.data, "parallel shards={shards}");
            assert_eq!(snap_b, want_snap, "parallel snap shards={shards}");
        }
    }

    #[test]
    fn transition_handles_disjoint_identical_and_self() {
        let mut rng = Rng::new(61);
        let w0 = random_w(&mut rng, 16, 16);
        // disjoint supports: union = a_nnz + b_nnz, overlap 0
        let all = rng.sample_indices(256, 40);
        let (ia, ib) = all.split_at(20);
        let mut ibs = ib.to_vec();
        ibs.sort_unstable();
        let a = SparseDelta::new(16, 16, ia.to_vec(), vec![1.5; 20]);
        let b = SparseDelta::new(16, 16, ibs, vec![-0.5; 20]);
        let tp = TransitionPlan::build(&a, &b, 3);
        assert_eq!(tp.overlap(), 0);
        assert_eq!(tp.union_nnz(), 40);
        let (want_w, want_snap) = revert_then_apply(&w0, &a, &b, 1.0, 1.0);
        let mut w = w0.clone();
        let snap_a = a.snapshot(&w);
        a.apply(&mut w, 1.0);
        let mut snap_b = vec![0.0f32; b.nnz()];
        tp.transition(&mut w, &snap_a, &mut snap_b, &b, 1.0);
        assert_eq!(w.data, want_w.data);
        assert_eq!(snap_b, want_snap);
        // self-transition A→A (identical supports, alpha change): full
        // overlap, and the result equals re-applying A at the new alpha.
        let tp = TransitionPlan::build(&a, &a, 2);
        assert_eq!(tp.overlap(), a.nnz());
        assert_eq!(tp.union_nnz(), a.nnz());
        let (want_w, want_snap) = revert_then_apply(&w0, &a, &a, 1.0, 0.25);
        let mut w = w0.clone();
        a.apply(&mut w, 1.0);
        let mut snap_b = vec![0.0f32; a.nnz()];
        tp.transition(&mut w, &snap_a, &mut snap_b, &a, 0.25);
        assert_eq!(w.data, want_w.data);
        assert_eq!(snap_b, want_snap);
    }

    #[test]
    fn prop_transition_bit_identical_to_revert_apply() {
        // The tentpole invariant: for random shapes, supports (any overlap
        // ratio, including empty sides) and alphas, the one-pass direct
        // transition produces exactly the bytes of revert-then-apply — on
        // both the weights and B's revert snapshot, serial and pooled.
        let pool = ThreadPool::new(4);
        pt::forall(
            62,
            30,
            |r| {
                let rows = 2 + r.below(24);
                let cols = 2 + r.below(24);
                let total = rows * cols;
                let ka = r.below(total);
                let kb = r.below(total);
                let shards = 1 + r.below(12);
                let alpha_a = -2.0 + 4.0 * r.uniform_f32();
                let alpha_b = -2.0 + 4.0 * r.uniform_f32();
                (r.next_u64(), rows, cols, ka, kb, shards, alpha_a, alpha_b)
            },
            |&(seed, rows, cols, ka, kb, shards, alpha_a, alpha_b)| {
                let mut rng = Rng::new(seed);
                let w0 = random_w(&mut rng, rows, cols);
                let a = random_delta(&mut rng, rows, cols, ka);
                let b = random_delta(&mut rng, rows, cols, kb);
                let tp = TransitionPlan::build(&a, &b, shards);
                if tp.union_nnz() + tp.overlap() != a.nnz() + b.nnz() {
                    return false; // |A∪B| + |A∩B| = |A| + |B|
                }
                let (want_w, want_snap) =
                    revert_then_apply(&w0, &a, &b, alpha_a, alpha_b);
                let snap_a = a.snapshot(&w0);
                let mut w = w0.clone();
                a.apply(&mut w, alpha_a);
                let mut snap_b = vec![0.0f32; b.nnz()];
                tp.transition(&mut w, &snap_a, &mut snap_b, &b, alpha_b);
                if w.data != want_w.data || snap_b != want_snap {
                    return false;
                }
                let mut w = w0.clone();
                a.apply(&mut w, alpha_a);
                let mut snap_b = vec![0.0f32; b.nnz()];
                tp.transition_parallel(&mut w, &snap_a, &mut snap_b, &b, alpha_b, &pool);
                w.data == want_w.data && snap_b == want_snap
            },
        );
    }

    #[test]
    fn shard_sorted_is_row_aligned_on_any_sorted_slice() {
        let mut rng = Rng::new(63);
        for &(cols, k, n) in &[(32usize, 500usize, 6usize), (7, 40, 12), (16, 0, 3)] {
            let idx = rng.sample_indices(64 * cols, k);
            let plan = shard_sorted(&idx, cols, n);
            assert_eq!(plan.total(), idx.len());
            let mut covered = 0usize;
            for s in 0..plan.len() {
                let (lo, hi) = plan.range(s);
                assert_eq!(lo, covered);
                covered = hi;
                if lo > 0 && lo < idx.len() {
                    assert!(
                        idx[lo - 1] as usize / cols < idx[lo] as usize / cols,
                        "boundary splits a row"
                    );
                }
            }
            assert_eq!(covered, idx.len());
        }
    }

    #[test]
    fn to_dense_matches_apply_on_zero_base() {
        let mut rng = Rng::new(9);
        let d = random_delta(&mut rng, 8, 8, 6);
        let mut w = Tensor2::zeros(8, 8);
        d.apply(&mut w, 1.0);
        assert_eq!(w, d.to_dense());
    }

    #[test]
    fn run_plan_cuts_cover_runs_and_shard_bounds() {
        let mut rng = Rng::new(70);
        for &(rows, cols, k, n) in
            &[(64usize, 64usize, 900usize, 6usize), (8, 128, 300, 8), (4, 4, 0, 3), (16, 16, 1, 2)]
        {
            let d = random_delta(&mut rng, rows, cols, k);
            let shards = d.shard(n);
            let rp = RunPlan::build(&d.idx, &shards);
            // every shard bound must be a cut, and span() must find it
            for s in 0..shards.len() {
                let (lo, hi) = shards.range(s);
                let (_, len) = rp.span(lo, hi);
                assert!(len >= 1);
            }
            // walk the full span: cuts strictly increasing, runs truly
            // consecutive inside, breaks real at boundaries
            let (ptr, len) = rp.span(0, d.nnz());
            let cuts: Vec<u32> =
                (0..len).map(|i| unsafe { *ptr.add(i) }).collect();
            assert_eq!(cuts.first().copied(), Some(0));
            if d.nnz() > 0 {
                assert_eq!(cuts.last().copied(), Some(d.nnz() as u32));
            }
            assert!(cuts.windows(2).all(|w| w[0] < w[1]));
            for w2 in cuts.windows(2) {
                let (s, e) = (w2[0] as usize, w2[1] as usize);
                for p in s + 1..e {
                    assert_eq!(d.idx[p], d.idx[p - 1] + 1, "run not consecutive");
                }
            }
            assert_eq!(rp.n_runs(), cuts.len() - 1);
        }
    }

    #[test]
    fn tensor_plan_matches_shard_plan() {
        let mut rng = Rng::new(71);
        let d = random_delta(&mut rng, 32, 32, 400);
        let tp = TensorPlan::build(&d, 5);
        assert_eq!(tp.total(), d.nnz());
        assert_eq!(tp.shards.total(), d.shard(5).total());
        assert!(tp.nbytes() >= std::mem::size_of::<TensorPlan>());
    }

    #[test]
    fn prop_f16_resident_apply_matches_f32_of_decoded() {
        // Satellite (ISSUE 8): f16-resident apply ≡ f32-apply of the
        // decoded values, for random SHiRA adapters — weights, snapshot
        // and revert all bit-identical.
        pt::forall(
            72,
            30,
            |r| {
                let rows = 2 + r.below(24);
                let cols = 2 + r.below(24);
                let k = 1 + r.below(rows * cols);
                let alpha = -2.0 + 4.0 * r.uniform_f32();
                (r.next_u64(), rows, cols, k, alpha)
            },
            |&(seed, rows, cols, k, alpha)| {
                let mut rng = Rng::new(seed);
                let d = random_delta(&mut rng, rows, cols, k);
                let q = SparseDeltaF16::from_f32(&d);
                let dec = q.to_f32(); // exact widening of the quantized bits
                let w0 = random_w(&mut rng, rows, cols);
                let mut w16 = w0.clone();
                let mut s16 = vec![0.0f32; k];
                q.snapshot_apply(&mut w16, alpha, &mut s16);
                let mut w32 = w0.clone();
                let mut s32 = vec![0.0f32; k];
                dec.snapshot_apply(&mut w32, alpha, &mut s32);
                if w16.data != w32.data || s16 != s32 {
                    return false;
                }
                q.restore(&mut w16, &s16);
                w16.data == w0.data
            },
        );
    }

    #[test]
    fn f16_from_f32_roundtrips_representable_values() {
        let d = SparseDelta::new(2, 4, vec![0, 5], vec![1.5, -0.25]);
        let q = SparseDeltaF16::from_f32(&d);
        assert_eq!(q.to_f32(), d);
        assert_eq!(q.nnz(), 2);
        assert_eq!(q.numel(), 8);
        assert_eq!(q.nbytes(), 12);
        assert_eq!(q.shard(2).total(), 2);
    }
}
