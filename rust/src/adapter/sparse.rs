//! Sparse-delta algebra: the COO representation of a SHiRA adapter tensor
//! and the scatter hot path (paper §3.2, Fig. 3, Fig. 5).
//!
//! Representation: sorted unique flat indices (u32) + per-index delta
//! values (new_weight − base_weight at α = 1).  Application at strength α
//! is `W.flat[idx[i]] += α·delta[i]`; exact revert uses a base-value
//! snapshot taken at apply time (float-exact, unlike LoRA's W−αAB unfuse).
//!
//! For multi-core switching the sorted index array can be partitioned into
//! a row-aligned [`ShardPlan`]: shards own disjoint row ranges of W, so
//! `apply`/`restore`/`gather`/`merge` run shard-parallel with disjoint
//! writes and no false sharing on the output cache lines (DESIGN.md §3).
//! Every parallel path is bit-identical to its serial counterpart: each
//! element is touched by exactly one shard and the per-element arithmetic
//! is unchanged.

use crate::model::tensor::Tensor2;
use crate::util::threadpool::{SendPtr, ThreadPool};

/// Hard cap on shards per tensor; keeps [`ShardPlan`] a fixed-size (heap-
/// allocation-free) value, which the zero-alloc switch path relies on.
pub const MAX_SHARDS: usize = 64;

/// Below this many touched entries per operation, shard dispatch overhead
/// exceeds the scatter itself and engines stay serial (shared by the
/// switch and fusion engines so the thresholds cannot drift apart).
pub(crate) const PAR_MIN_NNZ: usize = 4096;

/// Target entries per shard (≈ a few cache-resident strides of work).
pub(crate) const NNZ_PER_SHARD: usize = 2048;

/// Shard count for an `nnz`-entry scatter on a `threads`-wide pool.
pub(crate) fn shards_for(nnz: usize, threads: usize) -> usize {
    (nnz / NNZ_PER_SHARD)
        .max(1)
        .min(threads * 2)
        .min(MAX_SHARDS)
}

/// Row-aligned partition of a sorted index array into `n` contiguous
/// ranges with near-equal nnz.  `bounds[s]..bounds[s+1]` is shard `s`'s
/// range into `idx`/`delta`; boundaries are snapped up to row boundaries
/// of the underlying matrix so two shards never write the same row.
#[derive(Clone, Copy, Debug)]
pub struct ShardPlan {
    n_shards: usize,
    bounds: [usize; MAX_SHARDS + 1],
}

impl ShardPlan {
    /// Number of shards in the plan.
    pub fn len(&self) -> usize {
        self.n_shards
    }

    /// True when the plan holds no shards (never produced by `shard`).
    pub fn is_empty(&self) -> bool {
        self.n_shards == 0
    }

    /// Index range `[lo, hi)` of shard `s`.
    pub fn range(&self, s: usize) -> (usize, usize) {
        (self.bounds[s], self.bounds[s + 1])
    }

    /// Total entries covered (== nnz of the delta the plan was built for).
    pub fn total(&self) -> usize {
        self.bounds[self.n_shards]
    }
}

/// Sparse delta for one weight tensor.
///
/// # Examples
///
/// Apply, then revert exactly from a snapshot (the SHiRA switching story):
///
/// ```
/// use shira::adapter::sparse::SparseDelta;
/// use shira::model::tensor::Tensor2;
///
/// let mut w = Tensor2::zeros(2, 4);
/// let d = SparseDelta::new(2, 4, vec![1, 6], vec![0.5, -2.0]);
/// let snap = d.snapshot(&w);
/// d.apply(&mut w, 1.0);
/// assert_eq!(w.data[1], 0.5);
/// assert_eq!(w.data[6], -2.0);
/// d.restore(&mut w, &snap);
/// assert!(w.data.iter().all(|&x| x == 0.0)); // bit-exact revert
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct SparseDelta {
    /// Rows of the target tensor.
    pub rows: usize,
    /// Columns of the target tensor.
    pub cols: usize,
    /// Sorted, unique flat indices (row-major).
    pub idx: Vec<u32>,
    /// delta[i] = finetuned_value − base_value at idx[i].
    pub delta: Vec<f32>,
}

impl SparseDelta {
    /// Build from sorted unique flat indices and their delta values.
    pub fn new(rows: usize, cols: usize, idx: Vec<u32>, delta: Vec<f32>) -> Self {
        assert_eq!(idx.len(), delta.len());
        debug_assert!(idx.windows(2).all(|w| w[0] < w[1]), "indices sorted+unique");
        debug_assert!(idx.iter().all(|&i| (i as usize) < rows * cols));
        SparseDelta {
            rows,
            cols,
            idx,
            delta,
        }
    }

    /// Number of nonzero entries.
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Elements of the target tensor (rows × cols).
    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    /// nnz / numel — the paper's 1–2% sparsity knob.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / self.numel() as f64
    }

    /// Bytes to store the adapter tensor (idx u32 + delta f32).
    pub fn nbytes(&self) -> usize {
        self.nnz() * 8
    }

    /// Build from a finetuned tensor vs its base: S = W' − W, keeping the
    /// entries at `idx` (the mask support).
    pub fn from_diff(base: &Tensor2, tuned_vals_at_idx: &[f32], idx: Vec<u32>) -> Self {
        let delta = idx
            .iter()
            .zip(tuned_vals_at_idx.iter())
            .map(|(&i, &v)| v - base.data[i as usize])
            .collect();
        SparseDelta::new(base.rows, base.cols, idx, delta)
    }

    // -- sharding ---------------------------------------------------------

    /// Partition the sorted index array into `n_shards` near-equal-nnz
    /// ranges, snapping each boundary up to the next row boundary of W.
    ///
    /// Row alignment means shard `s` and shard `s+1` write disjoint rows,
    /// so concurrent shards never contend for an output cache line (rows
    /// are ≥ 64 B apart for any serving-scale `cols`).  Cheap: O(n·run)
    /// where `run` is one row's nnz — recomputing per switch is noise next
    /// to the O(nnz) scatter itself.
    pub fn shard(&self, n_shards: usize) -> ShardPlan {
        let n = n_shards.clamp(1, MAX_SHARDS);
        let nnz = self.nnz();
        let mut bounds = [0usize; MAX_SHARDS + 1];
        let mut prev = 0usize;
        for s in 1..n {
            let mut t = (nnz * s / n).max(prev);
            if t > 0 && t < nnz && self.cols > 0 {
                let row = self.idx[t - 1] as usize / self.cols;
                while t < nnz && self.idx[t] as usize / self.cols == row {
                    t += 1;
                }
            }
            bounds[s] = t;
            prev = t;
        }
        bounds[n] = nnz;
        ShardPlan {
            n_shards: n,
            bounds,
        }
    }

    // -- scatter hot path -------------------------------------------------

    /// The scatter hot path: `W.flat[idx[i]] += α·delta[i]`.
    ///
    /// Indices are sorted, so writes walk memory monotonically — the
    /// cache-friendly order that makes SHiRA switching ~10× faster than a
    /// dense LoRA fuse at large dims (Fig. 5).
    #[inline]
    pub fn apply(&self, w: &mut Tensor2, alpha: f32) {
        debug_assert_eq!(w.rows, self.rows);
        debug_assert_eq!(w.cols, self.cols);
        unsafe { self.apply_raw(w.data.as_mut_ptr(), alpha, 0, self.nnz()) }
    }

    /// Shard-parallel scatter.  Bit-identical to [`Self::apply`] for any
    /// plan/thread count: indices are unique, so every element of W is
    /// written by exactly one shard with the same single `+=`.
    pub fn apply_parallel(
        &self,
        w: &mut Tensor2,
        alpha: f32,
        pool: &ThreadPool,
        plan: &ShardPlan,
    ) {
        debug_assert_eq!(w.rows, self.rows);
        debug_assert_eq!(w.cols, self.cols);
        debug_assert_eq!(plan.total(), self.nnz());
        let wp = SendPtr::new(w.data.as_mut_ptr());
        let plan = *plan;
        pool.scoped_for(plan.len(), move |s| {
            let (lo, hi) = plan.range(s);
            // SAFETY: shards cover disjoint idx ranges; idx entries are
            // unique and validated < rows*cols at construction.
            unsafe { self.apply_raw(wp.get(), alpha, lo, hi) }
        });
    }

    #[inline]
    unsafe fn apply_raw(&self, w: *mut f32, alpha: f32, lo: usize, hi: usize) {
        for j in lo..hi {
            let i = *self.idx.get_unchecked(j) as usize;
            *w.add(i) += alpha * *self.delta.get_unchecked(j);
        }
    }

    // -- snapshot / restore ----------------------------------------------

    /// Snapshot the base values at this delta's support (for exact revert).
    pub fn snapshot(&self, w: &Tensor2) -> Vec<f32> {
        self.idx.iter().map(|&i| w.data[i as usize]).collect()
    }

    /// Snapshot into a caller-owned buffer (the zero-allocation arena path).
    pub fn snapshot_into(&self, w: &Tensor2, out: &mut [f32]) {
        assert_eq!(out.len(), self.nnz());
        for (o, &i) in out.iter_mut().zip(self.idx.iter()) {
            *o = w.data[i as usize];
        }
    }

    /// Fused snapshot-then-apply over `[lo, hi)` — the switch hot path does
    /// both in one pass over the support (one load feeds both the snapshot
    /// store and the accumulate).
    #[inline]
    pub fn snapshot_apply_range(
        &self,
        w: &mut Tensor2,
        alpha: f32,
        snap: &mut [f32],
        lo: usize,
        hi: usize,
    ) {
        debug_assert_eq!(snap.len(), self.nnz());
        debug_assert!(lo <= hi && hi <= self.nnz());
        unsafe {
            self.snapshot_apply_raw(w.data.as_mut_ptr(), alpha, snap.as_mut_ptr(), lo, hi)
        }
    }

    /// Fused snapshot+apply over the whole support.
    pub fn snapshot_apply(&self, w: &mut Tensor2, alpha: f32, snap: &mut [f32]) {
        self.snapshot_apply_range(w, alpha, snap, 0, self.nnz());
    }

    /// Shard-parallel fused snapshot+apply.
    pub fn snapshot_apply_parallel(
        &self,
        w: &mut Tensor2,
        alpha: f32,
        snap: &mut [f32],
        pool: &ThreadPool,
        plan: &ShardPlan,
    ) {
        assert_eq!(snap.len(), self.nnz());
        debug_assert_eq!(plan.total(), self.nnz());
        let wp = SendPtr::new(w.data.as_mut_ptr());
        let sp = SendPtr::new(snap.as_mut_ptr());
        let plan = *plan;
        pool.scoped_for(plan.len(), move |s| {
            let (lo, hi) = plan.range(s);
            // SAFETY: disjoint idx ranges => disjoint W elements and
            // disjoint snapshot slots.
            unsafe { self.snapshot_apply_raw(wp.get(), alpha, sp.get(), lo, hi) }
        });
    }

    #[inline]
    unsafe fn snapshot_apply_raw(
        &self,
        w: *mut f32,
        alpha: f32,
        snap: *mut f32,
        lo: usize,
        hi: usize,
    ) {
        scatter_snapshot_apply(self.idx.as_ptr(), self.delta.as_ptr(), w, snap, alpha, lo, hi)
    }

    /// Exact revert: write back a snapshot taken before `apply`.
    pub fn restore(&self, w: &mut Tensor2, snapshot: &[f32]) {
        assert_eq!(snapshot.len(), self.nnz());
        unsafe {
            self.restore_raw(w.data.as_mut_ptr(), snapshot.as_ptr(), 0, self.nnz())
        }
    }

    /// Shard-parallel restore.  Bit-identical to [`Self::restore`]: pure
    /// stores of snapshotted values to disjoint locations.
    pub fn restore_parallel(
        &self,
        w: &mut Tensor2,
        snapshot: &[f32],
        pool: &ThreadPool,
        plan: &ShardPlan,
    ) {
        assert_eq!(snapshot.len(), self.nnz());
        debug_assert_eq!(plan.total(), self.nnz());
        let wp = SendPtr::new(w.data.as_mut_ptr());
        let plan = *plan;
        pool.scoped_for(plan.len(), move |s| {
            let (lo, hi) = plan.range(s);
            // SAFETY: disjoint idx ranges => disjoint W elements.
            unsafe { self.restore_raw(wp.get(), snapshot.as_ptr(), lo, hi) }
        });
    }

    #[inline]
    unsafe fn restore_raw(&self, w: *mut f32, snap: *const f32, lo: usize, hi: usize) {
        scatter_restore(self.idx.as_ptr(), w, snap, lo, hi)
    }

    // -- gather -----------------------------------------------------------

    /// Gather current values at the support.
    pub fn gather(&self, w: &Tensor2) -> Vec<f32> {
        self.idx.iter().map(|&i| w.data[i as usize]).collect()
    }

    /// Shard-parallel gather into a caller-owned buffer.
    pub fn gather_parallel(
        &self,
        w: &Tensor2,
        out: &mut [f32],
        pool: &ThreadPool,
        plan: &ShardPlan,
    ) {
        assert_eq!(out.len(), self.nnz());
        debug_assert_eq!(plan.total(), self.nnz());
        let op = SendPtr::new(out.as_mut_ptr());
        let wd = &w.data;
        let plan = *plan;
        pool.scoped_for(plan.len(), move |s| {
            let (lo, hi) = plan.range(s);
            for j in lo..hi {
                // SAFETY: disjoint out slots per shard; idx validated.
                unsafe {
                    *op.get().add(j) = wd[*self.idx.get_unchecked(j) as usize];
                }
            }
        });
    }

    // -- merge ------------------------------------------------------------

    /// Naive multi-adapter fusion (paper Fig. 3b): index-union merge,
    /// summing deltas where supports overlap.
    pub fn merge(&self, other: &SparseDelta) -> SparseDelta {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut idx = Vec::with_capacity(self.nnz() + other.nnz());
        let mut delta = Vec::with_capacity(self.nnz() + other.nnz());
        merge_ranges(
            &self.idx,
            &self.delta,
            &other.idx,
            &other.delta,
            &mut idx,
            &mut delta,
        );
        SparseDelta::new(self.rows, self.cols, idx, delta)
    }

    /// Shard-parallel union-merge, bit-identical to [`Self::merge`].
    ///
    /// Both supports are cut at the same flat-index thresholds (taken from
    /// `self`'s row-aligned plan), each shard's output size is counted in a
    /// first parallel pass, and shards then write disjoint output ranges.
    pub fn merge_parallel(
        &self,
        other: &SparseDelta,
        pool: &ThreadPool,
        n_shards: usize,
    ) -> SparseDelta {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let plan = self.shard(n_shards);
        let n = plan.len();
        if n <= 1 {
            return self.merge(other);
        }
        // Flat-index thresholds at shard starts; both arrays are cut there.
        let numel = self.numel() as u64;
        let mut thresh = [0u64; MAX_SHARDS + 1];
        thresh[n] = numel;
        for s in 1..n {
            let b = plan.bounds[s];
            thresh[s] = if b < self.nnz() {
                self.idx[b] as u64
            } else {
                numel
            };
        }
        let mut ob = [0usize; MAX_SHARDS + 1];
        ob[n] = other.nnz();
        for s in 1..n {
            ob[s] = other.idx.partition_point(|&i| (i as u64) < thresh[s]);
        }

        // Pass 1: per-shard union sizes (disjoint count slots).
        let mut counts = [0usize; MAX_SHARDS];
        let cp = SendPtr::new(counts.as_mut_ptr());
        pool.scoped_for(n, |s| {
            let (alo, ahi) = plan.range(s);
            let c = merge_count(&self.idx[alo..ahi], &other.idx[ob[s]..ob[s + 1]]);
            // SAFETY: one writer per slot.
            unsafe { *cp.get().add(s) = c }
        });
        let mut offs = [0usize; MAX_SHARDS + 1];
        for s in 0..n {
            offs[s + 1] = offs[s] + counts[s];
        }
        let total = offs[n];

        // Pass 2: write each shard's merged run at its offset.
        let mut out_idx = vec![0u32; total];
        let mut out_delta = vec![0f32; total];
        let oi = SendPtr::new(out_idx.as_mut_ptr());
        let od = SendPtr::new(out_delta.as_mut_ptr());
        pool.scoped_for(n, |s| {
            let (alo, ahi) = plan.range(s);
            // SAFETY: output ranges [offs[s], offs[s+1]) are disjoint.
            unsafe {
                merge_write(
                    &self.idx[alo..ahi],
                    &self.delta[alo..ahi],
                    &other.idx[ob[s]..ob[s + 1]],
                    &other.delta[ob[s]..ob[s + 1]],
                    oi.get().add(offs[s]),
                    od.get().add(offs[s]),
                );
            }
        });
        SparseDelta::new(self.rows, self.cols, out_idx, out_delta)
    }

    /// Scale the delta (the paper's α baked in permanently).
    pub fn scaled(&self, alpha: f32) -> SparseDelta {
        SparseDelta {
            rows: self.rows,
            cols: self.cols,
            idx: self.idx.clone(),
            delta: self.delta.iter().map(|d| d * alpha).collect(),
        }
    }

    /// |support(self) ∩ support(other)| — the collision count that drives
    /// multi-adapter interference (paper §3.2).
    pub fn overlap(&self, other: &SparseDelta) -> usize {
        let (mut a, mut b, mut n) = (0usize, 0usize, 0usize);
        while a < self.nnz() && b < other.nnz() {
            match self.idx[a].cmp(&other.idx[b]) {
                std::cmp::Ordering::Less => a += 1,
                std::cmp::Ordering::Greater => b += 1,
                std::cmp::Ordering::Equal => {
                    n += 1;
                    a += 1;
                    b += 1;
                }
            }
        }
        n
    }

    /// Number of nonzero entries of `selfᵀ · other` (both viewed as dense
    /// n×m matrices with these sparse supports).  An entry (c1, c2) of the
    /// product is nonzero only if some row r has self[r,c1] ≠ 0 and
    /// other[r,c2] ≠ 0 — the orthogonality diagnostic of paper §3.2.
    /// Returns (nnz, total = m²).
    ///
    /// Sorted row-major indices mean each row's columns are a contiguous
    /// run, so both supports are walked with two cursors — no per-row
    /// `Vec<Vec<u32>>` grouping pass and no allocation beyond the dedup
    /// set itself.
    pub fn ata_nnz(&self, other: &SparseDelta) -> (usize, usize) {
        use std::collections::HashSet;
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let cols = self.cols;
        let mut pairs: HashSet<u64> = HashSet::new();
        let (mut a, mut b) = (0usize, 0usize);
        while a < self.nnz() && b < other.nnz() {
            let ra = self.idx[a] as usize / cols;
            let rb = other.idx[b] as usize / cols;
            if ra < rb {
                a = row_run_end(&self.idx, a, cols);
            } else if rb < ra {
                b = row_run_end(&other.idx, b, cols);
            } else {
                let a_end = row_run_end(&self.idx, a, cols);
                let b_end = row_run_end(&other.idx, b, cols);
                for &i1 in &self.idx[a..a_end] {
                    let c1 = (i1 as usize % cols) as u64;
                    for &i2 in &other.idx[b..b_end] {
                        let c2 = (i2 as usize % cols) as u64;
                        pairs.insert(c1 << 32 | c2);
                    }
                }
                a = a_end;
                b = b_end;
            }
        }
        (pairs.len(), cols * cols)
    }

    /// Densify (tests / analysis only).
    pub fn to_dense(&self) -> Tensor2 {
        let mut t = Tensor2::zeros(self.rows, self.cols);
        for (&i, &d) in self.idx.iter().zip(self.delta.iter()) {
            t.data[i as usize] = d;
        }
        t
    }
}

/// The fused snapshot-then-apply scatter kernel over `[lo, hi)` — the one
/// definition shared by the serial path, the shard-parallel path, and the
/// switch engine's task list (so the bit-identity argument has a single
/// code location).
///
/// # Safety
/// `idx[lo..hi)` must be unique, in-bounds for `w`, and valid for `snap`
/// slot `j`; ranges handed to concurrent callers must be disjoint.
#[inline]
pub(crate) unsafe fn scatter_snapshot_apply(
    idx: *const u32,
    delta: *const f32,
    w: *mut f32,
    snap: *mut f32,
    alpha: f32,
    lo: usize,
    hi: usize,
) {
    for j in lo..hi {
        let i = *idx.add(j) as usize;
        let wp = w.add(i);
        let base = *wp;
        *snap.add(j) = base;
        *wp = base + alpha * *delta.add(j);
    }
}

/// Snapshot-restore kernel over `[lo, hi)` (see [`scatter_snapshot_apply`]).
///
/// # Safety
/// Same contract as [`scatter_snapshot_apply`].
#[inline]
pub(crate) unsafe fn scatter_restore(
    idx: *const u32,
    w: *mut f32,
    snap: *const f32,
    lo: usize,
    hi: usize,
) {
    for j in lo..hi {
        *w.add(*idx.add(j) as usize) = *snap.add(j);
    }
}

/// End of the run of entries sharing `idx[start]`'s row.
#[inline]
fn row_run_end(idx: &[u32], start: usize, cols: usize) -> usize {
    let row = idx[start] as usize / cols;
    let mut e = start + 1;
    while e < idx.len() && idx[e] as usize / cols == row {
        e += 1;
    }
    e
}

/// Two-pointer union size of two sorted unique index slices.
fn merge_count(a: &[u32], b: &[u32]) -> usize {
    let (mut i, mut j, mut c) = (0usize, 0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                i += 1;
                j += 1;
            }
        }
        c += 1;
    }
    c + (a.len() - i) + (b.len() - j)
}

/// Union-merge into Vecs (serial path).
fn merge_ranges(
    a_idx: &[u32],
    a_val: &[f32],
    b_idx: &[u32],
    b_val: &[f32],
    out_idx: &mut Vec<u32>,
    out_val: &mut Vec<f32>,
) {
    let (mut a, mut b) = (0usize, 0usize);
    while a < a_idx.len() || b < b_idx.len() {
        let ia = a_idx.get(a).copied().unwrap_or(u32::MAX);
        let ib = b_idx.get(b).copied().unwrap_or(u32::MAX);
        if ia < ib {
            out_idx.push(ia);
            out_val.push(a_val[a]);
            a += 1;
        } else if ib < ia {
            out_idx.push(ib);
            out_val.push(b_val[b]);
            b += 1;
        } else {
            out_idx.push(ia);
            out_val.push(a_val[a] + b_val[b]);
            a += 1;
            b += 1;
        }
    }
}

/// Union-merge into raw output cursors (parallel pass 2).
///
/// # Safety
/// `oi`/`od` must have room for `merge_count(a_idx, b_idx)` entries and be
/// written by exactly one shard.
unsafe fn merge_write(
    a_idx: &[u32],
    a_val: &[f32],
    b_idx: &[u32],
    b_val: &[f32],
    mut oi: *mut u32,
    mut od: *mut f32,
) {
    let (mut a, mut b) = (0usize, 0usize);
    while a < a_idx.len() || b < b_idx.len() {
        let ia = a_idx.get(a).copied().unwrap_or(u32::MAX);
        let ib = b_idx.get(b).copied().unwrap_or(u32::MAX);
        if ia < ib {
            *oi = ia;
            *od = a_val[a];
            a += 1;
        } else if ib < ia {
            *oi = ib;
            *od = b_val[b];
            b += 1;
        } else {
            *oi = ia;
            *od = a_val[a] + b_val[b];
            a += 1;
            b += 1;
        }
        oi = oi.add(1);
        od = od.add(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;
    use crate::util::rng::Rng;

    fn random_delta(rng: &mut Rng, rows: usize, cols: usize, k: usize) -> SparseDelta {
        let idx = rng.sample_indices(rows * cols, k);
        let mut delta = vec![0.0; k];
        rng.fill_normal(&mut delta, 0.0, 1.0);
        SparseDelta::new(rows, cols, idx, delta)
    }

    fn random_w(rng: &mut Rng, rows: usize, cols: usize) -> Tensor2 {
        let mut t = Tensor2::zeros(rows, cols);
        rng.fill_normal(&mut t.data, 0.0, 1.0);
        t
    }

    #[test]
    fn apply_changes_exactly_support() {
        let mut rng = Rng::new(1);
        let w0 = random_w(&mut rng, 16, 16);
        let d = random_delta(&mut rng, 16, 16, 10);
        let mut w = w0.clone();
        d.apply(&mut w, 1.0);
        let mut changed = 0;
        for i in 0..w.numel() {
            if w.data[i] != w0.data[i] {
                changed += 1;
                assert!(d.idx.contains(&(i as u32)));
            }
        }
        assert_eq!(changed, 10);
    }

    #[test]
    fn apply_alpha_scales() {
        let mut rng = Rng::new(2);
        let w0 = random_w(&mut rng, 8, 8);
        let d = random_delta(&mut rng, 8, 8, 5);
        let mut w_half = w0.clone();
        d.apply(&mut w_half, 0.5);
        for (j, &i) in d.idx.iter().enumerate() {
            let want = w0.data[i as usize] + 0.5 * d.delta[j];
            assert_eq!(w_half.data[i as usize], want);
        }
    }

    #[test]
    fn snapshot_restore_is_bit_exact() {
        let mut rng = Rng::new(3);
        let w0 = random_w(&mut rng, 32, 32);
        let d = random_delta(&mut rng, 32, 32, 64);
        let mut w = w0.clone();
        let snap = d.snapshot(&w);
        d.apply(&mut w, 1.7);
        assert!(w.max_abs_diff(&w0) > 0.0);
        d.restore(&mut w, &snap);
        assert_eq!(w.data, w0.data); // exact, not approx — the SHiRA claim
    }

    #[test]
    fn snapshot_into_matches_snapshot() {
        let mut rng = Rng::new(31);
        let w = random_w(&mut rng, 16, 16);
        let d = random_delta(&mut rng, 16, 16, 20);
        let mut buf = vec![0.0f32; 20];
        d.snapshot_into(&w, &mut buf);
        assert_eq!(buf, d.snapshot(&w));
    }

    #[test]
    fn fused_snapshot_apply_matches_two_pass() {
        let mut rng = Rng::new(32);
        let w0 = random_w(&mut rng, 24, 24);
        let d = random_delta(&mut rng, 24, 24, 48);
        let mut w1 = w0.clone();
        let snap1 = d.snapshot(&w1);
        d.apply(&mut w1, 0.8);
        let mut w2 = w0.clone();
        let mut snap2 = vec![0.0f32; d.nnz()];
        d.snapshot_apply(&mut w2, 0.8, &mut snap2);
        assert_eq!(w1.data, w2.data);
        assert_eq!(snap1, snap2);
    }

    #[test]
    fn from_diff_roundtrip() {
        let mut rng = Rng::new(4);
        let base = random_w(&mut rng, 8, 12);
        let idx = rng.sample_indices(96, 9);
        let tuned: Vec<f32> = idx.iter().map(|&i| base.data[i as usize] + 2.0).collect();
        let d = SparseDelta::from_diff(&base, &tuned, idx.clone());
        let mut w = base.clone();
        d.apply(&mut w, 1.0);
        for (&i, &t) in idx.iter().zip(tuned.iter()) {
            assert!((w.data[i as usize] - t).abs() < 1e-6);
        }
    }

    #[test]
    fn merge_unions_and_sums() {
        let a = SparseDelta::new(2, 4, vec![0, 3, 5], vec![1.0, 2.0, 3.0]);
        let b = SparseDelta::new(2, 4, vec![3, 6], vec![10.0, 20.0]);
        let m = a.merge(&b);
        assert_eq!(m.idx, vec![0, 3, 5, 6]);
        assert_eq!(m.delta, vec![1.0, 12.0, 3.0, 20.0]);
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let a = SparseDelta::new(2, 4, vec![1, 2], vec![1.0, 2.0]);
        let e = SparseDelta::new(2, 4, vec![], vec![]);
        assert_eq!(a.merge(&e), a);
        assert_eq!(e.merge(&a), a);
    }

    #[test]
    fn overlap_counts_shared_support() {
        let a = SparseDelta::new(4, 4, vec![0, 1, 8], vec![1.0; 3]);
        let b = SparseDelta::new(4, 4, vec![1, 8, 9], vec![1.0; 3]);
        assert_eq!(a.overlap(&b), 2);
        assert_eq!(b.overlap(&a), 2);
        assert_eq!(a.overlap(&a), 3);
    }

    #[test]
    fn ata_sparse_vs_dense_shapes() {
        // Two 1%-sparse adapters: product should be overwhelmingly zero.
        let mut rng = Rng::new(5);
        let n = 64;
        let k = (n * n) / 100;
        let a = random_delta(&mut rng, n, n, k);
        let b = random_delta(&mut rng, n, n, k);
        let (nnz, total) = a.ata_nnz(&b);
        assert!(total == n * n);
        assert!(
            (nnz as f64) < 0.05 * total as f64,
            "sparse product unexpectedly dense: {nnz}/{total}"
        );
    }

    #[test]
    fn ata_nnz_exact_small() {
        // a has (r0,c0)=(0,1); b has (0,2),(1,3): product nonzero only (1,2).
        let a = SparseDelta::new(2, 4, vec![1], vec![1.0]);
        let b = SparseDelta::new(2, 4, vec![2, 7], vec![1.0, 1.0]);
        let (nnz, total) = a.ata_nnz(&b);
        assert_eq!(nnz, 1);
        assert_eq!(total, 16);
    }

    #[test]
    fn ata_nnz_matches_dense_reference() {
        // Cross-check the run-based walk against a brute-force dense count.
        let mut rng = Rng::new(51);
        for _ in 0..10 {
            let (rows, cols) = (4 + rng.below(8), 4 + rng.below(8));
            let total = rows * cols;
            let a = random_delta(&mut rng, rows, cols, 1 + rng.below(total / 2));
            let b = random_delta(&mut rng, rows, cols, 1 + rng.below(total / 2));
            let da = a.to_dense();
            let db = b.to_dense();
            let mut want = 0usize;
            for c1 in 0..cols {
                for c2 in 0..cols {
                    let nz = (0..rows)
                        .any(|r| da.at(r, c1) != 0.0 && db.at(r, c2) != 0.0);
                    if nz {
                        want += 1;
                    }
                }
            }
            let (got, tot) = a.ata_nnz(&b);
            assert_eq!(got, want);
            assert_eq!(tot, cols * cols);
        }
    }

    #[test]
    fn shard_plan_is_row_aligned_partition() {
        let mut rng = Rng::new(52);
        for &(rows, cols, k, n) in
            &[(32usize, 32usize, 200usize, 4usize), (8, 128, 300, 8), (64, 16, 1, 7), (4, 4, 0, 3)]
        {
            let d = random_delta(&mut rng, rows, cols, k);
            let plan = d.shard(n);
            assert_eq!(plan.total(), d.nnz());
            let mut covered = 0usize;
            for s in 0..plan.len() {
                let (lo, hi) = plan.range(s);
                assert!(lo <= hi);
                assert_eq!(lo, covered);
                covered = hi;
                if s > 0 && lo > 0 && lo < d.nnz() {
                    let prev_row = d.idx[lo - 1] as usize / cols;
                    let this_row = d.idx[lo] as usize / cols;
                    assert!(prev_row < this_row, "boundary splits a row");
                }
            }
            assert_eq!(covered, d.nnz());
        }
    }

    #[test]
    fn parallel_apply_restore_bit_identical_for_any_thread_count() {
        // The tentpole invariant: shard-parallel scatter/restore produce
        // bytes equal to the serial path for thread counts 1, 2, N.
        let mut rng = Rng::new(53);
        let d = random_delta(&mut rng, 64, 64, 700);
        let w0 = random_w(&mut rng, 64, 64);
        let mut w_serial = w0.clone();
        d.apply(&mut w_serial, 1.3);
        for threads in [1usize, 2, 7] {
            let pool = ThreadPool::new(threads);
            let plan = d.shard(threads * 2);
            let mut w = w0.clone();
            let mut snap = vec![0.0f32; d.nnz()];
            d.snapshot_apply_parallel(&mut w, 1.3, &mut snap, &pool, &plan);
            assert_eq!(w.data, w_serial.data, "apply threads={threads}");
            assert_eq!(snap, d.snapshot(&w0), "snapshot threads={threads}");
            d.restore_parallel(&mut w, &snap, &pool, &plan);
            assert_eq!(w.data, w0.data, "restore threads={threads}");
            let mut w2 = w0.clone();
            d.apply_parallel(&mut w2, 1.3, &pool, &plan);
            assert_eq!(w2.data, w_serial.data, "apply_parallel threads={threads}");
        }
    }

    #[test]
    fn parallel_gather_matches_serial() {
        let mut rng = Rng::new(54);
        let d = random_delta(&mut rng, 32, 32, 100);
        let w = random_w(&mut rng, 32, 32);
        let pool = ThreadPool::new(3);
        let plan = d.shard(5);
        let mut out = vec![0.0f32; d.nnz()];
        d.gather_parallel(&w, &mut out, &pool, &plan);
        assert_eq!(out, d.gather(&w));
    }

    #[test]
    fn prop_parallel_merge_bit_identical() {
        let pool = ThreadPool::new(4);
        pt::forall(
            55,
            30,
            |r| {
                let rows = 2 + r.below(16);
                let cols = 2 + r.below(16);
                let total = rows * cols;
                let ka = 1 + r.below(total);
                let kb = 1 + r.below(total);
                (r.next_u64(), rows, cols, ka, kb)
            },
            |&(seed, rows, cols, ka, kb)| {
                let mut rng = Rng::new(seed);
                let a = random_delta(&mut rng, rows, cols, ka);
                let b = random_delta(&mut rng, rows, cols, kb);
                let serial = a.merge(&b);
                [1usize, 2, 5, 16].iter().all(|&n| {
                    let par = a.merge_parallel(&b, &pool, n);
                    par.idx == serial.idx && par.delta == serial.delta
                })
            },
        );
    }

    #[test]
    fn prop_parallel_apply_restore_bit_identical() {
        let pool = ThreadPool::new(4);
        pt::forall(
            56,
            25,
            |r| {
                let rows = 2 + r.below(24);
                let cols = 2 + r.below(24);
                let total = rows * cols;
                let k = 1 + r.below(total);
                let shards = 1 + r.below(12);
                let alpha = -2.0 + 4.0 * r.uniform_f32();
                (r.next_u64(), rows, cols, k, shards, alpha)
            },
            |&(seed, rows, cols, k, shards, alpha)| {
                let mut rng = Rng::new(seed);
                let d = random_delta(&mut rng, rows, cols, k);
                let w0 = random_w(&mut rng, rows, cols);
                let plan = d.shard(shards);
                let mut ws = w0.clone();
                d.apply(&mut ws, alpha);
                let mut wp = w0.clone();
                let mut snap = vec![0.0f32; d.nnz()];
                d.snapshot_apply_parallel(&mut wp, alpha, &mut snap, &pool, &plan);
                if wp.data != ws.data {
                    return false;
                }
                d.restore_parallel(&mut wp, &snap, &pool, &plan);
                wp.data == w0.data
            },
        );
    }

    #[test]
    fn prop_merge_commutes_on_disjoint_supports() {
        pt::forall(
            7,
            40,
            |r| {
                let rows = 4 + r.below(8);
                let cols = 4 + r.below(8);
                let total = rows * cols;
                let k1 = 1 + r.below(total / 2);
                let extra = r.below(total / 2);
                let all = r.sample_indices(total, (k1 + 1 + extra).min(total));
                let split = k1.min(all.len() - 1).max(1);
                (rows, cols, all, split)
            },
            |(rows, cols, all, split)| {
                let (i1, i2) = all.split_at(*split);
                let d1 = SparseDelta::new(
                    *rows,
                    *cols,
                    i1.to_vec(),
                    i1.iter().map(|&i| i as f32).collect(),
                );
                let mut i2s = i2.to_vec();
                i2s.sort_unstable();
                let d2 = SparseDelta::new(
                    *rows,
                    *cols,
                    i2s.clone(),
                    i2s.iter().map(|&i| -(i as f32)).collect(),
                );
                d1.merge(&d2) == d2.merge(&d1)
            },
        );
    }

    #[test]
    fn prop_apply_revert_exact_for_any_alpha_sequence() {
        // Serving invariant (DESIGN.md §7): any interleaving of
        // apply/revert pairs leaves the base bit-identical.
        pt::forall(
            8,
            30,
            |r| {
                let alphas: Vec<f32> = (0..1 + r.below(4))
                    .map(|_| -2.0 + 4.0 * r.uniform_f32())
                    .collect();
                (r.next_u64(), alphas)
            },
            |(seed, alphas)| {
                let mut rng = Rng::new(*seed);
                let w0 = random_w(&mut rng, 16, 16);
                let mut w = w0.clone();
                for &a in alphas {
                    let d = random_delta(&mut rng, 16, 16, 8);
                    let snap = d.snapshot(&w);
                    d.apply(&mut w, a);
                    d.restore(&mut w, &snap);
                }
                w.data == w0.data
            },
        );
    }

    #[test]
    fn to_dense_matches_apply_on_zero_base() {
        let mut rng = Rng::new(9);
        let d = random_delta(&mut rng, 8, 8, 6);
        let mut w = Tensor2::zeros(8, 8);
        d.apply(&mut w, 1.0);
        assert_eq!(w, d.to_dense());
    }
}
