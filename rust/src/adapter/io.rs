//! Versioned binary adapter file format (paper Fig. 3a: "sparse weights and
//! their indices").
//!
//! Layout (little-endian):
//!
//! ```text
//! magic   u32   0x53485241 ("SHRA") | 0x4C4F5241 ("LORA")
//! version u32   1
//! meta    u32 len + utf8 JSON  {name, strategy|scale}
//! count   u32   number of tensors
//! per tensor:
//!   name  u32 len + utf8
//!   rows  u32, cols u32
//!   SHRA: k u32, idx  u32[k],  delta f32[k]
//!   LORA: r u32, a f32[rows*r], b f32[r*cols]
//! crc     u64   FNV-1a over everything before it
//! ```

use std::io::{self, Read, Write};
use std::path::Path;

use super::sparse::SparseDelta;
use super::{LoraAdapter, LoraTensor, ShiraAdapter};
use crate::model::tensor::Tensor2;
use crate::util::json::{self, Json};

const MAGIC_SHIRA: u32 = 0x5348_5241;
const MAGIC_LORA: u32 = 0x4C4F_5241;
const VERSION: u32 = 1;

/// Errors from adapter (de)serialization.
#[derive(Debug)]
pub enum IoError {
    /// Underlying filesystem error.
    Io(io::Error),
    /// Structural problem: bad magic, checksum, truncation, bad indices.
    Format(String),
}

impl std::fmt::Display for IoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IoError::Io(e) => write!(f, "adapter io: {e}"),
            IoError::Format(m) => write!(f, "adapter format: {m}"),
        }
    }
}

impl std::error::Error for IoError {}

impl From<io::Error> for IoError {
    fn from(e: io::Error) -> Self {
        IoError::Io(e)
    }
}

// -- byte-level helpers -------------------------------------------------

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new() -> Self {
        Writer { buf: Vec::new() }
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.bytes(s.as_bytes());
    }

    fn f32s(&mut self, xs: &[f32]) {
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn u32s(&mut self, xs: &[u32]) {
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn finish(mut self) -> Vec<u8> {
        let crc = fnv64(&self.buf);
        self.buf.extend_from_slice(&crc.to_le_bytes());
        self.buf
    }
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn new(b: &'a [u8]) -> Result<Self, IoError> {
        if b.len() < 8 {
            return Err(IoError::Format("file too short".into()));
        }
        let body = &b[..b.len() - 8];
        let want = u64::from_le_bytes(b[b.len() - 8..].try_into().unwrap());
        if fnv64(body) != want {
            return Err(IoError::Format("checksum mismatch (corrupt file)".into()));
        }
        Ok(Reader { b: body, i: 0 })
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], IoError> {
        if self.i + n > self.b.len() {
            return Err(IoError::Format("truncated file".into()));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, IoError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String, IoError> {
        let n = self.u32()? as usize;
        if n > 1 << 20 {
            return Err(IoError::Format("string too long".into()));
        }
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|_| IoError::Format("bad utf8".into()))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, IoError> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u32s(&mut self, n: usize) -> Result<Vec<u32>, IoError> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

fn fnv64(b: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &x in b {
        h ^= x as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

// -- SHiRA ----------------------------------------------------------------

/// Serialize a SHiRA adapter to the versioned binary format (module docs).
pub fn encode_shira(a: &ShiraAdapter) -> Vec<u8> {
    let mut w = Writer::new();
    w.u32(MAGIC_SHIRA);
    w.u32(VERSION);
    let meta = Json::obj(vec![
        ("name", Json::str(&a.name)),
        ("strategy", Json::str(&a.strategy)),
    ]);
    w.str(&meta.to_string_compact());
    w.u32(a.tensors.len() as u32);
    for (name, d) in &a.tensors {
        w.str(name);
        w.u32(d.rows as u32);
        w.u32(d.cols as u32);
        w.u32(d.nnz() as u32);
        w.u32s(&d.idx);
        w.f32s(&d.delta);
    }
    w.finish()
}

/// Decode a SHiRA adapter, verifying checksum, magic, version and the
/// sorted-unique in-range index invariant.
pub fn decode_shira(bytes: &[u8]) -> Result<ShiraAdapter, IoError> {
    let mut r = Reader::new(bytes)?;
    if r.u32()? != MAGIC_SHIRA {
        return Err(IoError::Format("not a SHiRA adapter file".into()));
    }
    let ver = r.u32()?;
    if ver != VERSION {
        return Err(IoError::Format(format!("unsupported version {ver}")));
    }
    let meta = json::parse(&r.str()?)
        .map_err(|e| IoError::Format(format!("bad meta json: {e}")))?;
    let name = meta
        .get("name")
        .and_then(|j| j.as_str())
        .unwrap_or("unnamed")
        .to_string();
    let strategy = meta
        .get("strategy")
        .and_then(|j| j.as_str())
        .unwrap_or("unknown")
        .to_string();
    let count = r.u32()? as usize;
    let mut tensors = Vec::with_capacity(count);
    for _ in 0..count {
        let tname = r.str()?;
        let rows = r.u32()? as usize;
        let cols = r.u32()? as usize;
        let k = r.u32()? as usize;
        if k > rows * cols {
            return Err(IoError::Format(format!("{tname}: k > numel")));
        }
        let idx = r.u32s(k)?;
        let delta = r.f32s(k)?;
        if !idx.windows(2).all(|w| w[0] < w[1]) {
            return Err(IoError::Format(format!("{tname}: indices not sorted")));
        }
        if idx.iter().any(|&i| (i as usize) >= rows * cols) {
            return Err(IoError::Format(format!("{tname}: index out of range")));
        }
        tensors.push((tname, SparseDelta::new(rows, cols, idx, delta)));
    }
    Ok(ShiraAdapter {
        name,
        strategy,
        tensors,
    })
}

/// Write an encoded SHiRA adapter to `path`.
pub fn save_shira(path: &Path, a: &ShiraAdapter) -> Result<(), IoError> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(&encode_shira(a))?;
    Ok(())
}

/// Read and decode a SHiRA adapter from `path`.
pub fn load_shira(path: &Path) -> Result<ShiraAdapter, IoError> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    decode_shira(&bytes)
}

// -- LoRA -------------------------------------------------------------------

/// Serialize a LoRA adapter to the versioned binary format (module docs).
pub fn encode_lora(a: &LoraAdapter) -> Vec<u8> {
    let mut w = Writer::new();
    w.u32(MAGIC_LORA);
    w.u32(VERSION);
    let meta = Json::obj(vec![
        ("name", Json::str(&a.name)),
        ("scale", Json::num(a.scale as f64)),
    ]);
    w.str(&meta.to_string_compact());
    w.u32(a.tensors.len() as u32);
    for t in &a.tensors {
        w.str(&t.target);
        w.u32(t.a.rows as u32);
        w.u32(t.b.cols as u32);
        w.u32(t.a.cols as u32);
        w.f32s(&t.a.data);
        w.f32s(&t.b.data);
    }
    w.finish()
}

/// Decode a LoRA adapter, verifying checksum, magic and version.
pub fn decode_lora(bytes: &[u8]) -> Result<LoraAdapter, IoError> {
    let mut r = Reader::new(bytes)?;
    if r.u32()? != MAGIC_LORA {
        return Err(IoError::Format("not a LoRA adapter file".into()));
    }
    let ver = r.u32()?;
    if ver != VERSION {
        return Err(IoError::Format(format!("unsupported version {ver}")));
    }
    let meta = json::parse(&r.str()?)
        .map_err(|e| IoError::Format(format!("bad meta json: {e}")))?;
    let name = meta
        .get("name")
        .and_then(|j| j.as_str())
        .unwrap_or("unnamed")
        .to_string();
    let scale = meta
        .get("scale")
        .and_then(|j| j.as_f64())
        .unwrap_or(1.0) as f32;
    let count = r.u32()? as usize;
    let mut tensors = Vec::with_capacity(count);
    for _ in 0..count {
        let target = r.str()?;
        let rows = r.u32()? as usize;
        let cols = r.u32()? as usize;
        let rank = r.u32()? as usize;
        let a = Tensor2::from_vec(rows, rank, r.f32s(rows * rank)?);
        let b = Tensor2::from_vec(rank, cols, r.f32s(rank * cols)?);
        tensors.push(LoraTensor { target, a, b });
    }
    Ok(LoraAdapter {
        name,
        scale,
        tensors,
    })
}

/// Write an encoded LoRA adapter to `path`.
pub fn save_lora(path: &Path, a: &LoraAdapter) -> Result<(), IoError> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(&encode_lora(a))?;
    Ok(())
}

/// Read and decode a LoRA adapter from `path`.
pub fn load_lora(path: &Path) -> Result<LoraAdapter, IoError> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    decode_lora(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_shira() -> ShiraAdapter {
        let mut rng = Rng::new(1);
        let idx = rng.sample_indices(256, 12);
        let mut delta = vec![0.0; 12];
        rng.fill_normal(&mut delta, 0.0, 0.5);
        ShiraAdapter {
            name: "bluefire".into(),
            strategy: "snip".into(),
            tensors: vec![("l0.wq".into(), SparseDelta::new(16, 16, idx, delta))],
        }
    }

    fn sample_lora() -> LoraAdapter {
        let mut rng = Rng::new(2);
        let mut a = Tensor2::zeros(16, 4);
        let mut b = Tensor2::zeros(4, 16);
        rng.fill_normal(&mut a.data, 0.0, 0.1);
        rng.fill_normal(&mut b.data, 0.0, 0.1);
        LoraAdapter {
            name: "paint".into(),
            scale: 2.0,
            tensors: vec![LoraTensor {
                target: "l0.wq".into(),
                a,
                b,
            }],
        }
    }

    #[test]
    fn shira_roundtrip() {
        let a = sample_shira();
        let b = decode_shira(&encode_shira(&a)).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn lora_roundtrip() {
        let a = sample_lora();
        let b = decode_lora(&encode_lora(&a)).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("shira-io-test");
        let _ = std::fs::create_dir_all(&dir);
        let p = dir.join("a.shira");
        save_shira(&p, &sample_shira()).unwrap();
        assert_eq!(load_shira(&p).unwrap(), sample_shira());
        let p2 = dir.join("a.lora");
        save_lora(&p2, &sample_lora()).unwrap();
        assert_eq!(load_lora(&p2).unwrap(), sample_lora());
    }

    #[test]
    fn corruption_detected() {
        let mut bytes = encode_shira(&sample_shira());
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        match decode_shira(&bytes) {
            Err(IoError::Format(m)) => assert!(m.contains("checksum"), "{m}"),
            other => panic!("expected checksum error, got {other:?}"),
        }
    }

    #[test]
    fn wrong_magic_rejected() {
        let bytes = encode_lora(&sample_lora());
        assert!(decode_shira(&bytes).is_err());
        let bytes = encode_shira(&sample_shira());
        assert!(decode_lora(&bytes).is_err());
    }

    #[test]
    fn truncation_detected() {
        let bytes = encode_shira(&sample_shira());
        assert!(decode_shira(&bytes[..bytes.len() - 9]).is_err());
        assert!(decode_shira(&bytes[..4]).is_err());
    }

    #[test]
    fn size_matches_nnz_accounting() {
        let a = sample_shira();
        let bytes = encode_shira(&a);
        // idx+delta payload plus bounded header/meta overhead
        assert!(bytes.len() >= a.nbytes());
        assert!(bytes.len() < a.nbytes() + 256);
    }
}
