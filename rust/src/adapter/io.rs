//! Versioned binary adapter file format (paper Fig. 3a: "sparse weights and
//! their indices").
//!
//! Two on-disk versions are supported; [`Format`] selects what `encode_*_as`
//! writes, and the decoders accept either.
//!
//! **v1** layout (little-endian):
//!
//! ```text
//! magic   u32   0x53485241 ("SHRA") | 0x4C4F5241 ("LORA")
//! version u32   1
//! meta    u32 len + utf8 JSON  {name, strategy|scale}
//! count   u32   number of tensors
//! per tensor:
//!   name  u32 len + utf8
//!   rows  u32, cols u32
//!   SHRA: k u32, idx  u32[k],  delta f32[k]
//!   LORA: r u32, a f32[rows*r], b f32[r*cols]
//! crc     u64   FNV-1a over everything before it
//! ```
//!
//! **v2** layout — the flash-footprint format (ROADMAP: many adapters on
//! flash).  Indices are stored as **delta-encoded varints**: the sorted
//! row-major flat index sequence (row·cols + col) is turned into gaps
//! (`idx[0], idx[1]−idx[0], …`), each LEB128-encoded.  At the paper's 1–2%
//! sparsity gaps are ~50–100, so most take one byte instead of four.
//! Values are f32 by default (**bit-exact round-trip**) or, opt-in, f16
//! (`Format::V2F16`, lossy).  Every tensor carries its own FNV-1a CRC so
//! corruption is localized, and the v1 whole-file trailing CRC is kept:
//!
//! ```text
//! magic   u32, version u32 = 2, flags u8 (bit0: f16 values)
//! meta    u32 len + utf8 JSON
//! count   u32
//! per tensor:
//!   name  u32 len + utf8
//!   rows  u32, cols u32
//!   SHRA: k u32, gap_bytes u32, varint gaps, delta f32[k]|f16[k]
//!   LORA: r u32, a vals, b vals (f32 or f16 per flags)
//!   tcrc  u64   FNV-1a over this tensor's bytes (name..values)
//! crc     u64   FNV-1a over everything before it
//! ```

use std::io::{self, Read, Write};
use std::path::Path;

use super::sparse::{SparseDelta, SparseDeltaF16};
use super::{LoraAdapter, LoraTensor, ShiraAdapter, ShiraF16Adapter};
use crate::model::tensor::Tensor2;
use crate::util::json::{self, Json};

const MAGIC_SHIRA: u32 = 0x5348_5241;
const MAGIC_LORA: u32 = 0x4C4F_5241;
const VERSION_V1: u32 = 1;
const VERSION_V2: u32 = 2;
const FLAG_F16: u8 = 1;

/// On-disk format version selector for the `encode_*_as` entry points.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    /// Version 1: u32 indices + f32 values (the original layout).
    V1,
    /// Version 2: varint delta-coded indices + f32 values.  Bit-exact
    /// round-trip, ~30–40% smaller than v1 at 1–2% sparsity.
    V2,
    /// Version 2 with f16 values: smallest (~2–3× vs v1) but **lossy** —
    /// decode returns the nearest-even f16 of each value.  Not valid when
    /// serving must be bit-identical to the trained adapter.
    V2F16,
}

impl Format {
    /// Parse a CLI spelling: `v1`, `v2` or `v2-f16`.
    pub fn parse(s: &str) -> Option<Format> {
        match s {
            "v1" => Some(Format::V1),
            "v2" => Some(Format::V2),
            "v2-f16" => Some(Format::V2F16),
            _ => None,
        }
    }

    /// Stable CLI / report name.
    pub fn name(&self) -> &'static str {
        match self {
            Format::V1 => "v1",
            Format::V2 => "v2",
            Format::V2F16 => "v2-f16",
        }
    }

    fn f16(self) -> bool {
        matches!(self, Format::V2F16)
    }
}

/// Adapter family identified by a file's magic number.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdapterFamily {
    /// Sparse high-rank adapter ("SHRA" magic).
    Shira,
    /// Low-rank adapter ("LORA" magic).
    Lora,
}

/// Identify an encoded adapter's family from its magic number without
/// decoding (or checksumming) the file.
pub fn sniff_family(bytes: &[u8]) -> Option<AdapterFamily> {
    if bytes.len() < 4 {
        return None;
    }
    match u32::from_le_bytes(bytes[..4].try_into().unwrap()) {
        MAGIC_SHIRA => Some(AdapterFamily::Shira),
        MAGIC_LORA => Some(AdapterFamily::Lora),
        _ => None,
    }
}

/// Errors from adapter (de)serialization.
#[derive(Debug)]
pub enum IoError {
    /// Underlying filesystem error.
    Io(io::Error),
    /// Structural problem: bad magic, checksum, truncation, bad indices.
    Format(String),
}

impl IoError {
    /// True for failures that plausibly resolve on retry (interrupted or
    /// timed-out reads, transient unavailability).  Structural problems
    /// ([`IoError::Format`]: bad magic, CRC, truncation) are permanent —
    /// the bytes themselves are wrong, so retrying re-reads the same
    /// corruption; those feed the store's quarantine instead.
    pub fn is_transient(&self) -> bool {
        match self {
            IoError::Io(e) => matches!(
                e.kind(),
                io::ErrorKind::Interrupted
                    | io::ErrorKind::TimedOut
                    | io::ErrorKind::WouldBlock
            ),
            IoError::Format(_) => false,
        }
    }
}

impl std::fmt::Display for IoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IoError::Io(e) => write!(f, "adapter io: {e}"),
            IoError::Format(m) => write!(f, "adapter format: {m}"),
        }
    }
}

impl std::error::Error for IoError {}

impl From<io::Error> for IoError {
    fn from(e: io::Error) -> Self {
        IoError::Io(e)
    }
}

// -- half-float conversion ----------------------------------------------

/// f32 → IEEE 754 binary16 bits, round-to-nearest-even (no `half` crate in
/// the offline vendor set).
pub(crate) fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x7F_FFFF;
    if exp == 0xFF {
        // inf / nan (nan keeps a set mantissa bit)
        return sign | 0x7C00 | if man != 0 { 0x200 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return sign | 0x7C00; // overflow → inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflow → signed zero
        }
        // subnormal half: shift the 24-bit significand into 10 bits
        let m = man | 0x80_0000;
        let shift = (14 - e) as u32;
        let half = m >> shift;
        let rem = m & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let mut h = half;
        if rem > halfway || (rem == halfway && (h & 1) == 1) {
            h += 1; // may carry into the exponent — numerically correct
        }
        return sign | h as u16;
    }
    let mut h = ((e as u32) << 10) | (man >> 13);
    let round = man & 0x1FFF;
    if round > 0x1000 || (round == 0x1000 && (h & 1) == 1) {
        h += 1; // may carry into the exponent / infinity — correct
    }
    sign | h as u16
}

/// IEEE 754 binary16 bits → f32 (exact).
pub(crate) fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h >> 15) as u32) << 31;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x3FF) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign
        } else {
            // subnormal: renormalize
            let mut e = 113u32; // 127 - 15 + 1
            let mut m = man;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3FF) << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (man << 13)
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

// -- byte-level helpers -------------------------------------------------

fn push_varint(buf: &mut Vec<u8>, mut v: u32) {
    loop {
        let b = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(b);
            break;
        }
        buf.push(b | 0x80);
    }
}

/// Decode one LEB128 u32 at `b[i..]`; returns (value, bytes consumed).
fn varint_at(b: &[u8], i: usize) -> Result<(u32, usize), IoError> {
    let mut v = 0u32;
    let mut shift = 0u32;
    let mut j = i;
    loop {
        let Some(&byte) = b.get(j) else {
            return Err(IoError::Format("truncated varint".into()));
        };
        j += 1;
        if shift == 28 && (byte & 0xF0) != 0 {
            return Err(IoError::Format("varint overflows u32".into()));
        }
        v |= ((byte & 0x7F) as u32) << shift;
        if byte & 0x80 == 0 {
            return Ok((v, j - i));
        }
        shift += 7;
    }
}

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new() -> Self {
        Writer { buf: Vec::new() }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.bytes(s.as_bytes());
    }

    fn f32s(&mut self, xs: &[f32]) {
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn f16s(&mut self, xs: &[f32]) {
        for &x in xs {
            self.buf.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
        }
    }

    fn vals(&mut self, xs: &[f32], f16: bool) {
        if f16 {
            self.f16s(xs)
        } else {
            self.f32s(xs)
        }
    }

    fn u32s(&mut self, xs: &[u32]) {
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Current length — the start mark for a per-tensor CRC region.
    fn mark(&self) -> usize {
        self.buf.len()
    }

    /// Append the FNV-1a of everything written since `start`.
    fn tensor_crc(&mut self, start: usize) {
        let crc = fnv64(&self.buf[start..]);
        self.buf.extend_from_slice(&crc.to_le_bytes());
    }

    fn finish(mut self) -> Vec<u8> {
        let crc = fnv64(&self.buf);
        self.buf.extend_from_slice(&crc.to_le_bytes());
        self.buf
    }
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn new(b: &'a [u8]) -> Result<Self, IoError> {
        if b.len() < 8 {
            return Err(IoError::Format("file too short".into()));
        }
        let body = &b[..b.len() - 8];
        let want = u64::from_le_bytes(b[b.len() - 8..].try_into().unwrap());
        if fnv64(body) != want {
            return Err(IoError::Format("checksum mismatch (corrupt file)".into()));
        }
        Ok(Reader { b: body, i: 0 })
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], IoError> {
        if self.i + n > self.b.len() {
            return Err(IoError::Format("truncated file".into()));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, IoError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, IoError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, IoError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String, IoError> {
        let n = self.u32()? as usize;
        if n > 1 << 20 {
            return Err(IoError::Format("string too long".into()));
        }
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|_| IoError::Format("bad utf8".into()))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, IoError> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn f16s(&mut self, n: usize) -> Result<Vec<f32>, IoError> {
        let raw = self.take(n * 2)?;
        Ok(raw
            .chunks_exact(2)
            .map(|c| f16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    fn vals(&mut self, n: usize, f16: bool) -> Result<Vec<f32>, IoError> {
        if f16 {
            self.f16s(n)
        } else {
            self.f32s(n)
        }
    }

    /// Raw binary16 bits, NOT widened (the f16-resident decode path).
    fn u16s(&mut self, n: usize) -> Result<Vec<u16>, IoError> {
        let raw = self.take(n * 2)?;
        Ok(raw
            .chunks_exact(2)
            .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u32s(&mut self, n: usize) -> Result<Vec<u32>, IoError> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Current offset — the start mark for a per-tensor CRC region.
    fn pos(&self) -> usize {
        self.i
    }

    /// Read the per-tensor CRC and compare against bytes since `start`.
    fn check_tensor_crc(&mut self, start: usize, tname: &str) -> Result<(), IoError> {
        let got = fnv64(&self.b[start..self.i]);
        let want = self.u64()?;
        if got != want {
            return Err(IoError::Format(format!(
                "{tname}: tensor checksum mismatch"
            )));
        }
        Ok(())
    }
}

fn fnv64(b: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &x in b {
        h ^= x as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn checked_numel(rows: usize, cols: usize, tname: &str) -> Result<usize, IoError> {
    rows.checked_mul(cols)
        .ok_or_else(|| IoError::Format(format!("{tname}: rows*cols overflows")))
}

// -- SHiRA ----------------------------------------------------------------

/// Serialize a SHiRA adapter in the v1 layout (module docs).
pub fn encode_shira(a: &ShiraAdapter) -> Vec<u8> {
    let mut w = Writer::new();
    w.u32(MAGIC_SHIRA);
    w.u32(VERSION_V1);
    w.str(&shira_meta_json(a));
    w.u32(a.tensors.len() as u32);
    for (name, d) in &a.tensors {
        w.str(name);
        w.u32(d.rows as u32);
        w.u32(d.cols as u32);
        w.u32(d.nnz() as u32);
        w.u32s(&d.idx);
        w.f32s(&d.delta);
    }
    w.finish()
}

/// Serialize a SHiRA adapter in the chosen [`Format`].
pub fn encode_shira_as(a: &ShiraAdapter, fmt: Format) -> Vec<u8> {
    match fmt {
        Format::V1 => encode_shira(a),
        Format::V2 | Format::V2F16 => encode_shira_v2(a, fmt.f16()),
    }
}

fn shira_meta_json(a: &ShiraAdapter) -> String {
    Json::obj(vec![
        ("name", Json::str(&a.name)),
        ("strategy", Json::str(&a.strategy)),
    ])
    .to_string_compact()
}

fn encode_shira_v2(a: &ShiraAdapter, f16: bool) -> Vec<u8> {
    let mut w = Writer::new();
    w.u32(MAGIC_SHIRA);
    w.u32(VERSION_V2);
    w.u8(if f16 { FLAG_F16 } else { 0 });
    w.str(&shira_meta_json(a));
    w.u32(a.tensors.len() as u32);
    let mut gaps = Vec::new();
    for (name, d) in &a.tensors {
        let start = w.mark();
        w.str(name);
        w.u32(d.rows as u32);
        w.u32(d.cols as u32);
        w.u32(d.nnz() as u32);
        gaps.clear();
        let mut prev = 0u32;
        for (j, &i) in d.idx.iter().enumerate() {
            push_varint(&mut gaps, if j == 0 { i } else { i - prev });
            prev = i;
        }
        w.u32(gaps.len() as u32);
        w.bytes(&gaps);
        w.vals(&d.delta, f16);
        w.tensor_crc(start);
    }
    w.finish()
}

/// Decode a SHiRA adapter (either version), verifying checksums, magic,
/// version and the sorted-unique in-range index invariant.
pub fn decode_shira(bytes: &[u8]) -> Result<ShiraAdapter, IoError> {
    let mut r = Reader::new(bytes)?;
    if r.u32()? != MAGIC_SHIRA {
        return Err(IoError::Format("not a SHiRA adapter file".into()));
    }
    match r.u32()? {
        VERSION_V1 => decode_shira_v1(&mut r),
        VERSION_V2 => decode_shira_v2(&mut r),
        ver => Err(IoError::Format(format!("unsupported version {ver}"))),
    }
}

fn parse_shira_meta(r: &mut Reader) -> Result<(String, String), IoError> {
    let meta = json::parse(&r.str()?)
        .map_err(|e| IoError::Format(format!("bad meta json: {e}")))?;
    Ok((
        meta.get("name")
            .and_then(|j| j.as_str())
            .unwrap_or("unnamed")
            .to_string(),
        meta.get("strategy")
            .and_then(|j| j.as_str())
            .unwrap_or("unknown")
            .to_string(),
    ))
}

fn decode_shira_v1(r: &mut Reader) -> Result<ShiraAdapter, IoError> {
    let (name, strategy) = parse_shira_meta(r)?;
    let count = r.u32()? as usize;
    let mut tensors = Vec::new();
    for _ in 0..count {
        let tname = r.str()?;
        let rows = r.u32()? as usize;
        let cols = r.u32()? as usize;
        let k = r.u32()? as usize;
        let numel = checked_numel(rows, cols, &tname)?;
        if k > numel {
            return Err(IoError::Format(format!("{tname}: k > numel")));
        }
        let idx = r.u32s(k)?;
        let delta = r.f32s(k)?;
        if !idx.windows(2).all(|w| w[0] < w[1]) {
            return Err(IoError::Format(format!("{tname}: indices not sorted")));
        }
        if idx.iter().any(|&i| (i as usize) >= numel) {
            return Err(IoError::Format(format!("{tname}: index out of range")));
        }
        tensors.push((tname, SparseDelta::new(rows, cols, idx, delta)));
    }
    Ok(ShiraAdapter {
        name,
        strategy,
        tensors,
    })
}

/// The shared v2 per-tensor prefix: name, shape, nnz, and the varint
/// gap-encoded index list (validated sorted-unique and in-range). The
/// caller reads the values in whichever representation it keeps resident,
/// then checks the tensor CRC from `start`.
struct V2TensorHead {
    start: usize,
    tname: String,
    rows: usize,
    cols: usize,
    idx: Vec<u32>,
}

fn decode_v2_tensor_head(r: &mut Reader) -> Result<V2TensorHead, IoError> {
    let start = r.pos();
    let tname = r.str()?;
    let rows = r.u32()? as usize;
    let cols = r.u32()? as usize;
    let k = r.u32()? as usize;
    let numel = checked_numel(rows, cols, &tname)?;
    if k > numel {
        return Err(IoError::Format(format!("{tname}: k > numel")));
    }
    let gap_bytes = r.u32()? as usize;
    if k > gap_bytes {
        // every gap takes at least one byte
        return Err(IoError::Format(format!("{tname}: gap bytes < k")));
    }
    let graw = r.take(gap_bytes)?;
    let mut idx = Vec::with_capacity(k);
    let mut cursor = 0usize;
    let mut prev = 0u64;
    for j in 0..k {
        let (gap, used) = varint_at(graw, cursor)?;
        cursor += used;
        let next = if j == 0 {
            gap as u64
        } else {
            if gap == 0 {
                return Err(IoError::Format(format!("{tname}: indices not sorted")));
            }
            prev + gap as u64
        };
        if next >= numel as u64 {
            return Err(IoError::Format(format!("{tname}: index out of range")));
        }
        idx.push(next as u32);
        prev = next;
    }
    if cursor != graw.len() {
        return Err(IoError::Format(format!("{tname}: trailing gap bytes")));
    }
    Ok(V2TensorHead {
        start,
        tname,
        rows,
        cols,
        idx,
    })
}

fn decode_shira_v2(r: &mut Reader) -> Result<ShiraAdapter, IoError> {
    let flags = r.u8()?;
    if flags & !FLAG_F16 != 0 {
        return Err(IoError::Format(format!("unknown flags {flags:#04x}")));
    }
    let f16 = flags & FLAG_F16 != 0;
    let (name, strategy) = parse_shira_meta(r)?;
    let count = r.u32()? as usize;
    let mut tensors = Vec::new();
    for _ in 0..count {
        let h = decode_v2_tensor_head(r)?;
        let delta = r.vals(h.idx.len(), f16)?;
        r.check_tensor_crc(h.start, &h.tname)?;
        tensors.push((h.tname, SparseDelta::new(h.rows, h.cols, h.idx, delta)));
    }
    Ok(ShiraAdapter {
        name,
        strategy,
        tensors,
    })
}

/// Decode a `v2-f16` SHiRA file **keeping the raw binary16 delta bits**
/// (the store's f16-resident mode).
///
/// Only `v2-f16` files are accepted: for any other format the resident
/// `u16` bits would be a lossy re-quantization of the file, breaking the
/// invariant that f16-resident serving is bit-identical to f32 serving of
/// the same decoded file. Performs the same checksum, magic, version and
/// index validation as [`decode_shira`].
/// Cheap header sniff: is `bytes` a SHiRA `v2-f16` file? Inspects only
/// magic, version, and the f16 flag byte — no checksum or body validation,
/// so a `true` answer still requires a full [`decode_shira_f16`] to trust
/// the contents. Used by the store to route f16-resident decodes.
pub fn is_v2_f16(bytes: &[u8]) -> bool {
    bytes.len() > 8
        && u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) == MAGIC_SHIRA
        && u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) == VERSION_V2
        && bytes[8] & FLAG_F16 != 0
}

pub fn decode_shira_f16(bytes: &[u8]) -> Result<ShiraF16Adapter, IoError> {
    let mut r = Reader::new(bytes)?;
    if r.u32()? != MAGIC_SHIRA {
        return Err(IoError::Format("not a SHiRA adapter file".into()));
    }
    match r.u32()? {
        VERSION_V2 => {}
        ver => {
            return Err(IoError::Format(format!(
                "f16-resident decode requires v2-f16, got version {ver}"
            )));
        }
    }
    let flags = r.u8()?;
    if flags & !FLAG_F16 != 0 {
        return Err(IoError::Format(format!("unknown flags {flags:#04x}")));
    }
    if flags & FLAG_F16 == 0 {
        return Err(IoError::Format(
            "f16-resident decode requires v2-f16 values (file stores f32)".into(),
        ));
    }
    let (name, strategy) = parse_shira_meta(&mut r)?;
    let count = r.u32()? as usize;
    let mut tensors = Vec::new();
    for _ in 0..count {
        let h = decode_v2_tensor_head(&mut r)?;
        let bits = r.u16s(h.idx.len())?;
        r.check_tensor_crc(h.start, &h.tname)?;
        tensors.push((h.tname, SparseDeltaF16::new(h.rows, h.cols, h.idx, bits)));
    }
    Ok(ShiraF16Adapter {
        name,
        strategy,
        tensors,
    })
}

/// Write an encoded SHiRA adapter to `path` (v1 layout).
pub fn save_shira(path: &Path, a: &ShiraAdapter) -> Result<(), IoError> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(&encode_shira(a))?;
    Ok(())
}

/// Read and decode a SHiRA adapter from `path` (either version).
pub fn load_shira(path: &Path) -> Result<ShiraAdapter, IoError> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    decode_shira(&bytes)
}

// -- LoRA -------------------------------------------------------------------

/// Serialize a LoRA adapter in the v1 layout (module docs).
pub fn encode_lora(a: &LoraAdapter) -> Vec<u8> {
    let mut w = Writer::new();
    w.u32(MAGIC_LORA);
    w.u32(VERSION_V1);
    w.str(&lora_meta_json(a));
    w.u32(a.tensors.len() as u32);
    for t in &a.tensors {
        w.str(&t.target);
        w.u32(t.a.rows as u32);
        w.u32(t.b.cols as u32);
        w.u32(t.a.cols as u32);
        w.f32s(&t.a.data);
        w.f32s(&t.b.data);
    }
    w.finish()
}

/// Serialize a LoRA adapter in the chosen [`Format`].  (v2 keeps u32
/// framing — LoRA factors are dense, so only the f16 option shrinks it.)
pub fn encode_lora_as(a: &LoraAdapter, fmt: Format) -> Vec<u8> {
    match fmt {
        Format::V1 => encode_lora(a),
        Format::V2 | Format::V2F16 => encode_lora_v2(a, fmt.f16()),
    }
}

fn lora_meta_json(a: &LoraAdapter) -> String {
    Json::obj(vec![
        ("name", Json::str(&a.name)),
        ("scale", Json::num(a.scale as f64)),
    ])
    .to_string_compact()
}

fn encode_lora_v2(a: &LoraAdapter, f16: bool) -> Vec<u8> {
    let mut w = Writer::new();
    w.u32(MAGIC_LORA);
    w.u32(VERSION_V2);
    w.u8(if f16 { FLAG_F16 } else { 0 });
    w.str(&lora_meta_json(a));
    w.u32(a.tensors.len() as u32);
    for t in &a.tensors {
        let start = w.mark();
        w.str(&t.target);
        w.u32(t.a.rows as u32);
        w.u32(t.b.cols as u32);
        w.u32(t.a.cols as u32);
        w.vals(&t.a.data, f16);
        w.vals(&t.b.data, f16);
        w.tensor_crc(start);
    }
    w.finish()
}

/// Decode a LoRA adapter (either version), verifying checksums, magic and
/// version.
pub fn decode_lora(bytes: &[u8]) -> Result<LoraAdapter, IoError> {
    let mut r = Reader::new(bytes)?;
    if r.u32()? != MAGIC_LORA {
        return Err(IoError::Format("not a LoRA adapter file".into()));
    }
    match r.u32()? {
        VERSION_V1 => decode_lora_body(&mut r, VERSION_V1, false),
        VERSION_V2 => {
            let flags = r.u8()?;
            if flags & !FLAG_F16 != 0 {
                return Err(IoError::Format(format!("unknown flags {flags:#04x}")));
            }
            decode_lora_body(&mut r, VERSION_V2, flags & FLAG_F16 != 0)
        }
        ver => Err(IoError::Format(format!("unsupported version {ver}"))),
    }
}

fn decode_lora_body(r: &mut Reader, ver: u32, f16: bool) -> Result<LoraAdapter, IoError> {
    let meta = json::parse(&r.str()?)
        .map_err(|e| IoError::Format(format!("bad meta json: {e}")))?;
    let name = meta
        .get("name")
        .and_then(|j| j.as_str())
        .unwrap_or("unnamed")
        .to_string();
    let scale = meta
        .get("scale")
        .and_then(|j| j.as_f64())
        .unwrap_or(1.0) as f32;
    let count = r.u32()? as usize;
    let mut tensors = Vec::new();
    for _ in 0..count {
        let start = r.pos();
        let target = r.str()?;
        let rows = r.u32()? as usize;
        let cols = r.u32()? as usize;
        let rank = r.u32()? as usize;
        let a_len = checked_numel(rows, rank, &target)?;
        let b_len = checked_numel(rank, cols, &target)?;
        let a = Tensor2::from_vec(rows, rank, r.vals(a_len, f16)?);
        let b = Tensor2::from_vec(rank, cols, r.vals(b_len, f16)?);
        if ver == VERSION_V2 {
            r.check_tensor_crc(start, &target)?;
        }
        tensors.push(LoraTensor { target, a, b });
    }
    Ok(LoraAdapter {
        name,
        scale,
        tensors,
    })
}

/// Write an encoded LoRA adapter to `path` (v1 layout).
pub fn save_lora(path: &Path, a: &LoraAdapter) -> Result<(), IoError> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(&encode_lora(a))?;
    Ok(())
}

/// Read and decode a LoRA adapter from `path` (either version).
pub fn load_lora(path: &Path) -> Result<LoraAdapter, IoError> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    decode_lora(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;
    use crate::util::rng::Rng;

    fn sample_shira() -> ShiraAdapter {
        let mut rng = Rng::new(1);
        let idx = rng.sample_indices(256, 12);
        let mut delta = vec![0.0; 12];
        rng.fill_normal(&mut delta, 0.0, 0.5);
        ShiraAdapter {
            name: "bluefire".into(),
            strategy: "snip".into(),
            tensors: vec![("l0.wq".into(), SparseDelta::new(16, 16, idx, delta))],
        }
    }

    fn sample_lora() -> LoraAdapter {
        let mut rng = Rng::new(2);
        let mut a = Tensor2::zeros(16, 4);
        let mut b = Tensor2::zeros(4, 16);
        rng.fill_normal(&mut a.data, 0.0, 0.1);
        rng.fill_normal(&mut b.data, 0.0, 0.1);
        LoraAdapter {
            name: "paint".into(),
            scale: 2.0,
            tensors: vec![LoraTensor {
                target: "l0.wq".into(),
                a,
                b,
            }],
        }
    }

    fn random_shira(rng: &mut Rng, tensors: usize) -> ShiraAdapter {
        let tensors = (0..tensors)
            .map(|t| {
                let rows = 2 + rng.below(40);
                let cols = 2 + rng.below(40);
                let k = 1 + rng.below(rows * cols);
                let idx = rng.sample_indices(rows * cols, k);
                let mut delta = vec![0.0; k];
                rng.fill_normal(&mut delta, 0.0, 1.0);
                (format!("t{t}"), SparseDelta::new(rows, cols, idx, delta))
            })
            .collect();
        ShiraAdapter {
            name: "rand".into(),
            strategy: "rand".into(),
            tensors,
        }
    }

    fn random_lora(rng: &mut Rng, tensors: usize) -> LoraAdapter {
        let tensors = (0..tensors)
            .map(|t| {
                let rows = 2 + rng.below(24);
                let cols = 2 + rng.below(24);
                let rank = 1 + rng.below(6);
                let mut a = Tensor2::zeros(rows, rank);
                let mut b = Tensor2::zeros(rank, cols);
                rng.fill_normal(&mut a.data, 0.0, 1.0);
                rng.fill_normal(&mut b.data, 0.0, 1.0);
                LoraTensor {
                    target: format!("t{t}"),
                    a,
                    b,
                }
            })
            .collect();
        LoraAdapter {
            name: "rand".into(),
            scale: 1.5,
            tensors,
        }
    }

    #[test]
    fn shira_roundtrip() {
        let a = sample_shira();
        let b = decode_shira(&encode_shira(&a)).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn lora_roundtrip() {
        let a = sample_lora();
        let b = decode_lora(&encode_lora(&a)).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn v2_roundtrip_bit_exact() {
        let a = sample_shira();
        let enc = encode_shira_as(&a, Format::V2);
        let dec = decode_shira(&enc).unwrap();
        assert_eq!(a, dec);
        for (orig, back) in a.tensors[0].1.delta.iter().zip(&dec.tensors[0].1.delta) {
            assert_eq!(orig.to_bits(), back.to_bits());
        }
        let l = sample_lora();
        assert_eq!(l, decode_lora(&encode_lora_as(&l, Format::V2)).unwrap());
    }

    #[test]
    fn v2_smaller_than_v1_at_paper_sparsity() {
        // 2%-sparse 128×128: gaps ~50 → 1-byte varints.
        let mut rng = Rng::new(7);
        let n = 128;
        let k = (n * n) / 50;
        let idx = rng.sample_indices(n * n, k);
        let mut delta = vec![0.0; k];
        rng.fill_normal(&mut delta, 0.0, 0.5);
        let a = ShiraAdapter {
            name: "sz".into(),
            strategy: "rand".into(),
            tensors: vec![("w".into(), SparseDelta::new(n, n, idx, delta))],
        };
        let v1 = encode_shira(&a).len();
        let v2 = encode_shira_as(&a, Format::V2).len();
        let v2f16 = encode_shira_as(&a, Format::V2F16).len();
        assert!(v2 < v1, "v2={v2} not smaller than v1={v1}");
        assert!(v2f16 < v2, "v2f16={v2f16} not smaller than v2={v2}");
        // ~5.x bytes/entry vs 8 for v1; f16 drops to ~3.x
        assert!((v2 as f64) < 0.8 * v1 as f64, "v2={v2} v1={v1}");
        assert!((v2f16 as f64) < 0.55 * v1 as f64, "v2f16={v2f16} v1={v1}");
    }

    #[test]
    fn v2_f16_roundtrip_is_close_and_idx_exact() {
        let a = sample_shira();
        let dec = decode_shira(&encode_shira_as(&a, Format::V2F16)).unwrap();
        assert_eq!(a.tensors[0].1.idx, dec.tensors[0].1.idx);
        for (orig, back) in a.tensors[0].1.delta.iter().zip(&dec.tensors[0].1.delta) {
            assert!((orig - back).abs() <= orig.abs() * 1e-3 + 1e-6, "{orig} {back}");
        }
        let l = sample_lora();
        let ldec = decode_lora(&encode_lora_as(&l, Format::V2F16)).unwrap();
        assert_eq!(l.tensors[0].target, ldec.tensors[0].target);
        assert_eq!(l.scale, ldec.scale);
    }

    #[test]
    fn f16_resident_decode_matches_f32_decode() {
        // The store's f16-resident path must see exactly the values the
        // f32 decode of the same v2-f16 file sees: same indices, and bits
        // that widen to bit-identical f32s.
        let mut rng = Rng::new(93);
        for _ in 0..8 {
            let a = random_shira(&mut rng, 1 + rng.below(3));
            let bytes = encode_shira_as(&a, Format::V2F16);
            let f32d = decode_shira(&bytes).unwrap();
            let f16d = decode_shira_f16(&bytes).unwrap();
            assert_eq!(f16d.name, f32d.name);
            assert_eq!(f16d.tensors.len(), f32d.tensors.len());
            for ((n16, d16), (n32, d32)) in f16d.tensors.iter().zip(&f32d.tensors) {
                assert_eq!(n16, n32);
                assert_eq!(d16.idx, d32.idx);
                assert_eq!(d16.nnz(), d32.nnz());
                for (b, v) in d16.bits.iter().zip(&d32.delta) {
                    assert_eq!(f16_bits_to_f32(*b).to_bits(), v.to_bits());
                }
            }
        }
    }

    #[test]
    fn f16_resident_decode_rejects_non_f16() {
        let a = sample_shira();
        for f in [Format::V1, Format::V2] {
            assert!(
                matches!(
                    decode_shira_f16(&encode_shira_as(&a, f)),
                    Err(IoError::Format(_))
                ),
                "{} accepted by f16-resident decode",
                f.name()
            );
        }
        assert!(decode_shira_f16(&encode_lora(&sample_lora())).is_err());
        let mut bytes = encode_shira_as(&a, Format::V2F16);
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(decode_shira_f16(&bytes).is_err());
    }

    #[test]
    fn v2_f16_sniff() {
        let a = sample_shira();
        assert!(is_v2_f16(&encode_shira_as(&a, Format::V2F16)));
        assert!(!is_v2_f16(&encode_shira_as(&a, Format::V2)));
        assert!(!is_v2_f16(&encode_shira_as(&a, Format::V1)));
        assert!(!is_v2_f16(&encode_lora(&sample_lora())));
        assert!(!is_v2_f16(&[]));
    }

    #[test]
    fn f16_conversion_exhaustive_roundtrip() {
        // Every non-NaN half value survives f16 → f32 → f16 exactly; NaNs
        // stay NaN.
        for h in 0..=u16::MAX {
            let x = f16_bits_to_f32(h);
            if x.is_nan() {
                assert!(f16_bits_to_f32(f32_to_f16_bits(x)).is_nan());
            } else {
                assert_eq!(f32_to_f16_bits(x), h, "h={h:#06x} x={x}");
            }
        }
    }

    #[test]
    fn format_parse_names() {
        for f in [Format::V1, Format::V2, Format::V2F16] {
            assert_eq!(Format::parse(f.name()), Some(f));
        }
        assert_eq!(Format::parse("v3"), None);
    }

    #[test]
    fn sniff_identifies_family() {
        assert_eq!(
            sniff_family(&encode_shira(&sample_shira())),
            Some(AdapterFamily::Shira)
        );
        assert_eq!(
            sniff_family(&encode_lora_as(&sample_lora(), Format::V2)),
            Some(AdapterFamily::Lora)
        );
        assert_eq!(sniff_family(&[1, 2, 3]), None);
        assert_eq!(sniff_family(&[0; 16]), None);
    }

    #[test]
    fn prop_roundtrip_random_adapters_all_formats() {
        // Satellite: random SHiRA/LoRA adapters survive v1 and v2
        // bit-exactly; v2-f16 preserves structure with close values.
        pt::forall(
            21,
            25,
            |r| (r.next_u64(), 1 + r.below(4)),
            |&(seed, nt)| {
                let mut rng = Rng::new(seed);
                let s = random_shira(&mut rng, nt);
                let l = random_lora(&mut rng, nt);
                let s_ok = decode_shira(&encode_shira_as(&s, Format::V1)).unwrap() == s
                    && decode_shira(&encode_shira_as(&s, Format::V2)).unwrap() == s;
                let l_ok = decode_lora(&encode_lora_as(&l, Format::V1)).unwrap() == l
                    && decode_lora(&encode_lora_as(&l, Format::V2)).unwrap() == l;
                let f16 = decode_shira(&encode_shira_as(&s, Format::V2F16)).unwrap();
                let f16_ok = f16
                    .tensors
                    .iter()
                    .zip(&s.tensors)
                    .all(|((_, d), (_, o))| d.idx == o.idx && d.nnz() == o.nnz());
                s_ok && l_ok && f16_ok
            },
        );
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("shira-io-test");
        let _ = std::fs::create_dir_all(&dir);
        let p = dir.join("a.shira");
        save_shira(&p, &sample_shira()).unwrap();
        assert_eq!(load_shira(&p).unwrap(), sample_shira());
        let p2 = dir.join("a.lora");
        save_lora(&p2, &sample_lora()).unwrap();
        assert_eq!(load_lora(&p2).unwrap(), sample_lora());
    }

    #[test]
    fn corruption_detected() {
        let mut bytes = encode_shira(&sample_shira());
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        match decode_shira(&bytes) {
            Err(IoError::Format(m)) => assert!(m.contains("checksum"), "{m}"),
            other => panic!("expected checksum error, got {other:?}"),
        }
    }

    #[test]
    fn corruption_fuzz_every_truncation_and_flip() {
        // Satellite: every truncation and every single-byte flip of every
        // format must return IoError::Format — never panic, never decode.
        let shira_files: Vec<Vec<u8>> = [Format::V1, Format::V2, Format::V2F16]
            .iter()
            .map(|&f| encode_shira_as(&sample_shira(), f))
            .collect();
        let lora_files: Vec<Vec<u8>> = [Format::V1, Format::V2, Format::V2F16]
            .iter()
            .map(|&f| encode_lora_as(&sample_lora(), f))
            .collect();
        for bytes in &shira_files {
            for len in 0..bytes.len() {
                assert!(
                    matches!(decode_shira(&bytes[..len]), Err(IoError::Format(_))),
                    "truncation to {len} not rejected"
                );
            }
            for p in 0..bytes.len() {
                let mut b = bytes.clone();
                b[p] ^= 0xFF;
                assert!(
                    matches!(decode_shira(&b), Err(IoError::Format(_))),
                    "flip at {p} not rejected"
                );
            }
        }
        for bytes in &lora_files {
            for len in 0..bytes.len() {
                assert!(
                    matches!(decode_lora(&bytes[..len]), Err(IoError::Format(_))),
                    "lora truncation to {len} not rejected"
                );
            }
            for p in 0..bytes.len() {
                let mut b = bytes.clone();
                b[p] ^= 0xFF;
                assert!(
                    matches!(decode_lora(&b), Err(IoError::Format(_))),
                    "lora flip at {p} not rejected"
                );
            }
        }
    }

    #[test]
    fn wrong_magic_rejected() {
        let bytes = encode_lora(&sample_lora());
        assert!(decode_shira(&bytes).is_err());
        let bytes = encode_shira(&sample_shira());
        assert!(decode_lora(&bytes).is_err());
        let bytes = encode_shira_as(&sample_shira(), Format::V2);
        assert!(decode_lora(&bytes).is_err());
    }

    #[test]
    fn truncation_detected() {
        let bytes = encode_shira(&sample_shira());
        assert!(decode_shira(&bytes[..bytes.len() - 9]).is_err());
        assert!(decode_shira(&bytes[..4]).is_err());
    }

    #[test]
    fn size_matches_nnz_accounting() {
        let a = sample_shira();
        let bytes = encode_shira(&a);
        // idx+delta payload plus bounded header/meta overhead
        assert!(bytes.len() >= a.nbytes());
        assert!(bytes.len() < a.nbytes() + 256);
    }
}
