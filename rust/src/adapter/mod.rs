//! Adapter types: SHiRA (sparse high-rank), LoRA, DoRA — the artifacts the
//! coordinator trains, stores, switches and fuses.

pub mod io;
pub mod kernel;
pub mod mask;
pub mod sparse;

use crate::model::tensor::Tensor2;
use sparse::{SparseDelta, SparseDeltaF16};

/// One LoRA target: W' = W + scale · A @ B.
#[derive(Clone, Debug, PartialEq)]
pub struct LoraTensor {
    /// Name of the weight tensor this delta applies to.
    pub target: String,
    /// Left factor, shape (n, r).
    pub a: Tensor2,
    /// Right factor, shape (r, m).
    pub b: Tensor2,
}

impl LoraTensor {
    /// The adapter rank r (= `a.cols`).
    pub fn rank(&self) -> usize {
        self.a.cols
    }

    /// Trainable parameters in this target (|A| + |B|).
    pub fn param_count(&self) -> usize {
        self.a.numel() + self.b.numel()
    }
}

/// A trained LoRA adapter (baseline).
#[derive(Clone, Debug, PartialEq)]
pub struct LoraAdapter {
    /// Adapter name (unique within a store).
    pub name: String,
    /// Effective fuse scale (= lora_alpha / rank).
    pub scale: f32,
    /// One low-rank delta per target tensor.
    pub tensors: Vec<LoraTensor>,
}

impl LoraAdapter {
    /// Trainable parameters across all targets.
    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.param_count()).sum()
    }

    /// Stored bytes (f32 per parameter).
    pub fn nbytes(&self) -> usize {
        self.param_count() * 4
    }

    /// Entries of the base model REWRITTEN when fused: every element of
    /// every target tensor (the %C column of paper Table 2).
    pub fn changed_entries(&self) -> usize {
        self.tensors
            .iter()
            .map(|t| t.a.rows * t.b.cols)
            .sum()
    }

    /// The delta for `target`, if this adapter touches it.
    pub fn find(&self, target: &str) -> Option<&LoraTensor> {
        self.tensors.iter().find(|t| t.target == target)
    }
}

/// A trained SHiRA adapter: one sparse delta per target tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct ShiraAdapter {
    /// Adapter name (unique within a store).
    pub name: String,
    /// Strategy used to build the mask (metadata; "merged" after fusion).
    pub strategy: String,
    /// (target tensor name, sparse delta) pairs.
    pub tensors: Vec<(String, SparseDelta)>,
}

impl ShiraAdapter {
    /// Trainable parameters = total nnz across targets.
    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|(_, d)| d.nnz()).sum()
    }

    /// Stored bytes: idx (u32) + delta (f32) per entry.
    pub fn nbytes(&self) -> usize {
        self.tensors.iter().map(|(_, d)| d.nbytes()).sum()
    }

    /// Entries rewritten at switch time (the %C column): exactly nnz.
    pub fn changed_entries(&self) -> usize {
        self.param_count()
    }

    /// The sparse delta for `target`, if this adapter touches it.
    pub fn find(&self, target: &str) -> Option<&SparseDelta> {
        self.tensors
            .iter()
            .find(|(n, _)| n == target)
            .map(|(_, d)| d)
    }

    /// Naive multi-adapter fusion (paper Fig. 3b): per-target union-merge.
    pub fn fuse_with(&self, other: &ShiraAdapter, name: &str) -> ShiraAdapter {
        let mut tensors = Vec::with_capacity(self.tensors.len());
        for (tname, d) in &self.tensors {
            let merged = match other.find(tname) {
                Some(od) => d.merge(od),
                None => d.clone(),
            };
            tensors.push((tname.clone(), merged));
        }
        // targets only in `other`
        for (tname, od) in &other.tensors {
            if self.find(tname).is_none() {
                tensors.push((tname.clone(), od.clone()));
            }
        }
        ShiraAdapter {
            name: name.to_string(),
            strategy: "merged".to_string(),
            tensors,
        }
    }

    /// Average per-target support overlap fraction with another adapter —
    /// the interference diagnostic of §3.2.
    pub fn overlap_fraction(&self, other: &ShiraAdapter) -> f64 {
        let mut inter = 0usize;
        let mut denom = 0usize;
        for (tname, d) in &self.tensors {
            if let Some(od) = other.find(tname) {
                inter += d.overlap(od);
                denom += d.nnz().min(od.nnz());
            }
        }
        if denom == 0 {
            0.0
        } else {
            inter as f64 / denom as f64
        }
    }
}

/// A SHiRA adapter whose delta values stay f16-resident (raw binary16
/// bits) — the store's halved-footprint residency mode (DESIGN.md §15).
/// Same sorted supports as [`ShiraAdapter`]; values are widened to f32
/// lane-wise inside the kernel on apply.  Widening is exact, so serving
/// this is bit-identical to serving [`ShiraF16Adapter::to_shira`]'s f32
/// materialization.
#[derive(Clone, Debug, PartialEq)]
pub struct ShiraF16Adapter {
    /// Adapter name (unique within a store).
    pub name: String,
    /// Strategy used to build the mask (metadata).
    pub strategy: String,
    /// (target tensor name, f16-resident sparse delta) pairs.
    pub tensors: Vec<(String, SparseDeltaF16)>,
}

impl ShiraF16Adapter {
    /// Trainable parameters = total nnz across targets.
    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|(_, d)| d.nnz()).sum()
    }

    /// Resident bytes: idx (u32) + bits (u16) per entry.
    pub fn nbytes(&self) -> usize {
        self.tensors.iter().map(|(_, d)| d.nbytes()).sum()
    }

    /// The f16-resident delta for `target`, if this adapter touches it.
    pub fn find(&self, target: &str) -> Option<&SparseDeltaF16> {
        self.tensors
            .iter()
            .find(|(n, _)| n == target)
            .map(|(_, d)| d)
    }

    /// Exact f32 materialization (used when an f16-resident member joins
    /// a fused set, where the fusion engine folds f32 contributor values).
    pub fn to_shira(&self) -> ShiraAdapter {
        ShiraAdapter {
            name: self.name.clone(),
            strategy: self.strategy.clone(),
            tensors: self
                .tensors
                .iter()
                .map(|(n, d)| (n.clone(), d.to_f32()))
                .collect(),
        }
    }
}

/// Precomputed direct A→B switch layout across every target tensor: one
/// merged-support [`TransitionPlan`](sparse::TransitionPlan) per tensor
/// of the incoming adapter, positional with its `tensors` vec.
///
/// Built off the serving thread (the store's transition-plan prefetch) and
/// consumed by `SwitchEngine::transition_to`, which walks each union
/// support once and dispatches all tensors' shards as ONE pool wave —
/// instead of revert+apply's two full passes and two dispatch waves.
///
/// # Examples
///
/// ```
/// use shira::adapter::sparse::SparseDelta;
/// use shira::adapter::{AdapterTransition, ShiraAdapter};
///
/// let mk = |name: &str, idx: Vec<u32>| ShiraAdapter {
///     name: name.into(),
///     strategy: "rand".into(),
///     tensors: vec![(
///         "w".into(),
///         SparseDelta::new(4, 4, idx.clone(), vec![1.0; idx.len()]),
///     )],
/// };
/// let a = mk("a", vec![0, 5, 9]);
/// let b = mk("b", vec![5, 7]);
/// let t = AdapterTransition::build(&a, &b, 4).unwrap();
/// assert_eq!((t.from.as_str(), t.to.as_str()), ("a", "b"));
/// assert_eq!(t.union_nnz(), 4); // {0, 5, 7, 9}
/// assert_eq!(t.overlap_nnz(), 1); // slot 5
/// assert!(t.matches(&a, &b));
/// assert!(!t.matches(&b, &a));
/// ```
#[derive(Clone, Debug)]
pub struct AdapterTransition {
    /// Name of the outgoing (currently-applied) adapter.
    pub from: String,
    /// Name of the incoming adapter.
    pub to: String,
    /// Per-tensor plans, positional with the incoming adapter's `tensors`.
    plans: Vec<sparse::TransitionPlan>,
}

impl AdapterTransition {
    /// Build the pairwise plan set for switching `from` → `to`, sharded
    /// for a `threads`-wide pool.  Returns `None` when the two adapters do
    /// not target the same tensor set (the engine falls back to
    /// revert+apply for such pairs).
    pub fn build(
        from: &ShiraAdapter,
        to: &ShiraAdapter,
        threads: usize,
    ) -> Option<AdapterTransition> {
        if from.tensors.len() != to.tensors.len() {
            return None;
        }
        let mut plans = Vec::with_capacity(to.tensors.len());
        for (target, d_to) in &to.tensors {
            let d_from = from.find(target)?;
            if (d_from.rows, d_from.cols) != (d_to.rows, d_to.cols) {
                return None;
            }
            let union = d_from.nnz() + d_to.nnz() - d_from.overlap(d_to);
            plans.push(sparse::TransitionPlan::build(
                d_from,
                d_to,
                sparse::shards_for(union, threads),
            ));
        }
        Some(AdapterTransition {
            from: from.name.clone(),
            to: to.name.clone(),
            plans,
        })
    }

    /// Per-tensor plans, positional with the `to` adapter's `tensors`.
    pub fn plans(&self) -> &[sparse::TransitionPlan] {
        &self.plans
    }

    /// Total union-support entries across all tensors — the slots one
    /// direct transition touches (vs `a_nnz + b_nnz` for revert+apply).
    pub fn union_nnz(&self) -> usize {
        self.plans.iter().map(|p| p.union_nnz()).sum()
    }

    /// Total overlapping entries across all tensors.
    pub fn overlap_nnz(&self) -> usize {
        self.plans.iter().map(|p| p.overlap()).sum()
    }

    /// Heap bytes held by the plan set (the plan-cache accounting unit).
    pub fn nbytes(&self) -> usize {
        self.plans.iter().map(|p| p.nbytes()).sum::<usize>()
            + self.from.len()
            + self.to.len()
            + std::mem::size_of::<AdapterTransition>()
    }

    /// Cheap validation that this plan set describes exactly the
    /// `from` → `to` pair (names, tensor count, per-tensor shapes and nnz).
    /// The engine refuses a non-matching plan and falls back.
    pub fn matches(&self, from: &ShiraAdapter, to: &ShiraAdapter) -> bool {
        from.name == self.from
            && to.name == self.to
            && from.tensors.len() == to.tensors.len()
            && to.tensors.len() == self.plans.len()
            && to.tensors.iter().zip(&self.plans).all(|((t, d), p)| {
                p.b_nnz() == d.nnz()
                    && (p.rows(), p.cols()) == (d.rows, d.cols)
                    && from.find(t).map(|fd| fd.nnz()) == Some(p.a_nnz())
            })
    }
}

/// %Params metric used across the paper's tables: adapter trainable params
/// relative to the base model's total.
pub fn pct(x: usize, total: usize) -> f64 {
    100.0 * x as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn delta(rng: &mut Rng, rows: usize, cols: usize, k: usize) -> SparseDelta {
        let idx = rng.sample_indices(rows * cols, k);
        let mut v = vec![0.0; k];
        rng.fill_normal(&mut v, 0.0, 0.1);
        SparseDelta::new(rows, cols, idx, v)
    }

    fn shira(rng: &mut Rng, name: &str) -> ShiraAdapter {
        ShiraAdapter {
            name: name.to_string(),
            strategy: "rand".to_string(),
            tensors: vec![
                ("l0.wq".into(), delta(rng, 16, 16, 5)),
                ("l0.wk".into(), delta(rng, 16, 16, 5)),
            ],
        }
    }

    #[test]
    fn shira_counts() {
        let mut rng = Rng::new(1);
        let a = shira(&mut rng, "a");
        assert_eq!(a.param_count(), 10);
        assert_eq!(a.nbytes(), 80);
        assert_eq!(a.changed_entries(), 10);
    }

    #[test]
    fn lora_counts() {
        let l = LoraAdapter {
            name: "l".into(),
            scale: 2.0,
            tensors: vec![LoraTensor {
                target: "l0.wq".into(),
                a: Tensor2::zeros(16, 4),
                b: Tensor2::zeros(4, 16),
            }],
        };
        assert_eq!(l.param_count(), 128);
        assert_eq!(l.changed_entries(), 256); // whole tensor rewritten on fuse
        assert_eq!(l.tensors[0].rank(), 4);
    }

    #[test]
    fn fuse_with_unions_targets() {
        let mut rng = Rng::new(2);
        let a = shira(&mut rng, "a");
        let mut b = shira(&mut rng, "b");
        b.tensors.push(("l0.wv".into(), delta(&mut rng, 16, 16, 3)));
        let f = a.fuse_with(&b, "a+b");
        assert_eq!(f.tensors.len(), 3);
        assert_eq!(f.strategy, "merged");
        let wq = f.find("l0.wq").unwrap();
        assert!(wq.nnz() >= 5 && wq.nnz() <= 10);
    }

    #[test]
    fn overlap_fraction_bounds() {
        let mut rng = Rng::new(3);
        let a = shira(&mut rng, "a");
        let b = shira(&mut rng, "b");
        let f = a.overlap_fraction(&b);
        assert!((0.0..=1.0).contains(&f));
        assert_eq!(a.overlap_fraction(&a), 1.0);
    }

    #[test]
    fn pct_math() {
        assert_eq!(pct(1, 100), 1.0);
        assert_eq!(pct(0, 5), 0.0);
    }

    #[test]
    fn shira_f16_adapter_counts_and_materializes() {
        let mut rng = Rng::new(5);
        let a = shira(&mut rng, "a");
        let q = ShiraF16Adapter {
            name: a.name.clone(),
            strategy: a.strategy.clone(),
            tensors: a
                .tensors
                .iter()
                .map(|(n, d)| (n.clone(), SparseDeltaF16::from_f32(d)))
                .collect(),
        };
        assert_eq!(q.param_count(), a.param_count());
        assert_eq!(q.nbytes(), a.param_count() * 6);
        assert!(q.find("l0.wq").is_some());
        assert!(q.find("nope").is_none());
        let m = q.to_shira();
        assert_eq!(m.name, a.name);
        assert_eq!(m.param_count(), a.param_count());
        // values round-trip through f16 narrow+widen within quantization
        for ((_, md), (_, ad)) in m.tensors.iter().zip(&a.tensors) {
            assert_eq!(md.idx, ad.idx);
            for (x, y) in md.delta.iter().zip(&ad.delta) {
                assert!((x - y).abs() < 1e-2);
            }
        }
    }

    #[test]
    fn adapter_transition_builds_and_validates() {
        let mut rng = Rng::new(4);
        let a = shira(&mut rng, "a");
        let b = shira(&mut rng, "b");
        let t = AdapterTransition::build(&a, &b, 4).expect("same target sets");
        assert_eq!(t.plans().len(), 2);
        assert_eq!(
            t.union_nnz() + t.overlap_nnz(),
            a.param_count() + b.param_count()
        );
        assert!(t.nbytes() > 0);
        assert!(t.matches(&a, &b));
        assert!(!t.matches(&b, &a), "direction matters");
        let c = shira(&mut rng, "c");
        assert!(!t.matches(&a, &c), "wrong incoming adapter");
        // different target sets are unplannable
        let mut d = shira(&mut rng, "d");
        d.tensors.pop();
        assert!(AdapterTransition::build(&a, &d, 4).is_none());
        let mut e = shira(&mut rng, "e");
        e.tensors[0].0 = "other".into();
        assert!(AdapterTransition::build(&a, &e, 4).is_none());
    }
}
