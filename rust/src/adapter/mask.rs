//! The five SHiRA mask strategies (paper §3.1).
//!
//! A mask is a set of flat indices into one target weight tensor; the
//! calibrator in `train::calibrate` produces the gradient statistics that
//! Grad and SNIP need (via the `*_grad_probe` artifacts).

use crate::model::tensor::Tensor2;
use crate::util::rng::Rng;

/// How a SHiRA mask (the trainable-entry set) is chosen (paper §3.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MaskStrategy {
    /// Structured: evenly spaced trainable rows + the (wrapped) diagonal —
    /// a rank-1-ish structure plus a high-rank diagonal (paper: SHiRA-Struct).
    Struct,
    /// Uniformly random 1-2% of entries (SHiRA-Rand).
    Rand,
    /// Top-k by |weight| (SHiRA-WM).
    WeightMagnitude,
    /// Top-k by accumulated |gradient| on a calibration set (SHiRA-Grad).
    Grad,
    /// Top-k by |weight·gradient| (SHiRA-SNIP, Lee et al. 2018).
    Snip,
}

impl MaskStrategy {
    /// Stable CLI / report name of the strategy.
    pub fn name(&self) -> &'static str {
        match self {
            MaskStrategy::Struct => "struct",
            MaskStrategy::Rand => "rand",
            MaskStrategy::WeightMagnitude => "wm",
            MaskStrategy::Grad => "grad",
            MaskStrategy::Snip => "snip",
        }
    }

    /// Parse a strategy name as produced by [`Self::name`].
    pub fn parse(s: &str) -> Option<MaskStrategy> {
        Some(match s {
            "struct" => MaskStrategy::Struct,
            "rand" => MaskStrategy::Rand,
            "wm" => MaskStrategy::WeightMagnitude,
            "grad" => MaskStrategy::Grad,
            "snip" => MaskStrategy::Snip,
            _ => return None,
        })
    }

    /// Does this strategy require calibration gradient statistics?
    pub fn needs_gradients(&self) -> bool {
        matches!(self, MaskStrategy::Grad | MaskStrategy::Snip)
    }

    /// All five strategies, in the paper's presentation order.
    pub fn all() -> [MaskStrategy; 5] {
        [
            MaskStrategy::Struct,
            MaskStrategy::Rand,
            MaskStrategy::WeightMagnitude,
            MaskStrategy::Grad,
            MaskStrategy::Snip,
        ]
    }
}

/// Generate the mask for one target tensor.
///
/// * `k` — exact number of trainable entries required (matches the AOT
///   theta layout, so every strategy must return exactly k indices).
/// * `grad_abs` — accumulated |grad| per entry (required by Grad/Snip).
/// * `rng` — stream for Rand (and for tie-breaking top-k jitter).
pub fn generate_mask(
    strategy: MaskStrategy,
    w: &Tensor2,
    k: usize,
    grad_abs: Option<&[f32]>,
    rng: &mut Rng,
) -> Vec<u32> {
    let n = w.numel();
    assert!(k <= n, "mask k={k} exceeds numel={n}");
    match strategy {
        MaskStrategy::Rand => rng.sample_indices(n, k),
        MaskStrategy::WeightMagnitude => {
            top_k_indices(&w.data, k, |_, x| x.abs())
        }
        MaskStrategy::Grad => {
            let g = grad_abs.expect("SHiRA-Grad requires gradient statistics");
            assert_eq!(g.len(), n);
            top_k_indices(g, k, |_, x| x)
        }
        MaskStrategy::Snip => {
            let g = grad_abs.expect("SHiRA-SNIP requires gradient statistics");
            assert_eq!(g.len(), n);
            top_k_indices(g, k, |i, x| x * w.data[i].abs())
        }
        MaskStrategy::Struct => struct_mask(w.rows, w.cols, k),
    }
}

/// Indices of the k largest entries by `key(i, data[i])`, sorted ascending.
/// Deterministic: ties broken by index.
fn top_k_indices(data: &[f32], k: usize, key: impl Fn(usize, f32) -> f32) -> Vec<u32> {
    let mut order: Vec<u32> = (0..data.len() as u32).collect();
    let score = |i: u32| key(i as usize, data[i as usize]);
    if k < data.len() {
        order.select_nth_unstable_by(k, |&a, &b| {
            score(b)
                .partial_cmp(&score(a))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        order.truncate(k);
    }
    order.sort_unstable();
    order
}

/// SHiRA-Struct: the wrapped diagonal (high rank) plus evenly spaced full
/// rows (the rank-1 component), filled to exactly k entries.
fn struct_mask(rows: usize, cols: usize, k: usize) -> Vec<u32> {
    let numel = rows * cols;
    let mut picked = vec![false; numel];
    let mut out: Vec<u32> = Vec::with_capacity(k);
    let push = |i: usize, picked: &mut Vec<bool>, out: &mut Vec<u32>| {
        if !picked[i] && out.len() < k {
            picked[i] = true;
            out.push(i as u32);
        }
    };
    // 1. wrapped diagonal: (i, i % cols) for every row — high rank.
    for i in 0..rows.min(k) {
        push(i * cols + (i % cols), &mut picked, &mut out);
    }
    // 2. evenly spaced full rows until the budget is filled.
    let remaining = k.saturating_sub(out.len());
    let n_rows = remaining.div_ceil(cols).min(rows);
    if n_rows > 0 {
        let stride = rows.max(1) as f64 / n_rows as f64;
        for j in 0..n_rows {
            let r = ((j as f64 + 0.5) * stride) as usize % rows;
            for c in 0..cols {
                push(r * cols + c, &mut picked, &mut out);
            }
        }
    }
    // 3. pad with the first unpicked entries (exact-k contract).
    for i in 0..numel {
        if out.len() >= k {
            break;
        }
        push(i, &mut picked, &mut out);
    }
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w(rows: usize, cols: usize, seed: u64) -> Tensor2 {
        let mut rng = Rng::new(seed);
        let mut t = Tensor2::zeros(rows, cols);
        rng.fill_normal(&mut t.data, 0.0, 1.0);
        t
    }

    fn assert_valid(idx: &[u32], k: usize, numel: usize) {
        assert_eq!(idx.len(), k);
        assert!(idx.windows(2).all(|p| p[0] < p[1]));
        assert!(idx.iter().all(|&i| (i as usize) < numel));
    }

    #[test]
    fn every_strategy_returns_exactly_k_valid_indices() {
        let t = w(32, 24, 1);
        let g: Vec<f32> = t.data.iter().map(|x| x.abs() * 0.5 + 0.1).collect();
        let mut rng = Rng::new(2);
        for s in MaskStrategy::all() {
            for k in [1, 7, 76, 200] {
                let idx = generate_mask(s, &t, k, Some(&g), &mut rng);
                assert_valid(&idx, k, 32 * 24);
            }
        }
    }

    #[test]
    fn wm_picks_largest_magnitudes() {
        let mut t = Tensor2::zeros(4, 4);
        t.data[3] = -10.0;
        t.data[7] = 9.0;
        t.data[11] = 0.5;
        let mut rng = Rng::new(0);
        let idx = generate_mask(MaskStrategy::WeightMagnitude, &t, 2, None, &mut rng);
        assert_eq!(idx, vec![3, 7]);
    }

    #[test]
    fn grad_picks_largest_gradients() {
        let t = w(4, 4, 3);
        let mut g = vec![0.0f32; 16];
        g[5] = 100.0;
        g[9] = 50.0;
        g[2] = 49.0;
        let mut rng = Rng::new(0);
        let idx = generate_mask(MaskStrategy::Grad, &t, 2, Some(&g), &mut rng);
        assert_eq!(idx, vec![5, 9]);
    }

    #[test]
    fn snip_multiplies_weight_and_grad() {
        let mut t = Tensor2::zeros(2, 2);
        t.data = vec![10.0, 1.0, 1.0, 1.0];
        let g = vec![1.0f32, 5.0, 0.1, 0.1];
        // snip scores: 10, 5, 0.1, 0.1
        let mut rng = Rng::new(0);
        let idx = generate_mask(MaskStrategy::Snip, &t, 2, Some(&g), &mut rng);
        assert_eq!(idx, vec![0, 1]);
    }

    #[test]
    fn rand_is_seed_deterministic() {
        let t = w(16, 16, 4);
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = generate_mask(MaskStrategy::Rand, &t, 10, None, &mut r1);
        let b = generate_mask(MaskStrategy::Rand, &t, 10, None, &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    fn struct_mask_contains_diagonal() {
        let t = w(16, 16, 6);
        let mut rng = Rng::new(0);
        // k large enough for diagonal + one row
        let idx = generate_mask(MaskStrategy::Struct, &t, 40, None, &mut rng);
        for i in 0..16u32 {
            assert!(idx.contains(&(i * 16 + i)), "diagonal entry {i} missing");
        }
    }

    #[test]
    fn struct_mask_is_high_rank() {
        // Rank of the mask (as a 0/1 matrix) must exceed any low-rank
        // adapter's: diagonal support alone gives full rank.
        let n = 24;
        let idx = struct_mask(n, n, n + 2 * n); // diag + ~2 rows
        let mut m = vec![vec![0.0f64; n]; n];
        for &i in &idx {
            m[(i as usize) / n][(i as usize) % n] = 1.0;
        }
        // Gaussian elimination rank.
        let mut rank = 0;
        for col in 0..n {
            if let Some(p) = (rank..n).find(|&r| m[r][col].abs() > 1e-9) {
                m.swap(rank, p);
                let pivot = m[rank][col];
                for r in 0..n {
                    if r != rank && m[r][col].abs() > 1e-9 {
                        let f = m[r][col] / pivot;
                        for c in 0..n {
                            m[r][c] -= f * m[rank][c];
                        }
                    }
                }
                rank += 1;
            }
        }
        assert!(rank >= n - 1, "struct mask rank {rank} < {}", n - 1);
    }

    #[test]
    fn top_k_ties_break_by_index() {
        let data = vec![1.0f32; 8];
        let idx = top_k_indices(&data, 3, |_, x| x);
        assert_eq!(idx, vec![0, 1, 2]);
    }

    #[test]
    fn strategies_differ_on_same_tensor() {
        let t = w(32, 32, 7);
        let g: Vec<f32> = (0..1024).map(|i| (1024 - i) as f32).collect();
        let mut rng = Rng::new(8);
        let k = 50;
        let wm = generate_mask(MaskStrategy::WeightMagnitude, &t, k, Some(&g), &mut rng);
        let gr = generate_mask(MaskStrategy::Grad, &t, k, Some(&g), &mut rng);
        let rd = generate_mask(MaskStrategy::Rand, &t, k, Some(&g), &mut rng);
        assert_ne!(wm, gr);
        assert_ne!(wm, rd);
    }

    #[test]
    fn parse_roundtrip() {
        for s in MaskStrategy::all() {
            assert_eq!(MaskStrategy::parse(s.name()), Some(s));
        }
        assert_eq!(MaskStrategy::parse("nope"), None);
    }
}
