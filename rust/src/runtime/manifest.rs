//! Typed view of `artifacts/manifest.json` — the contract between the
//! build-time python AOT pipeline and the rust runtime.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// Element dtype of an artifact input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    I32,
}

impl DType {
    fn parse(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "i32" => Some(DType::I32),
            _ => None,
        }
    }
}

/// One input or output of a compiled artifact.
#[derive(Clone, Debug)]
pub struct IoSpec {
    /// Parameter name as lowered.
    pub name: String,
    /// Element dtype.
    pub dtype: DType,
    /// Row-major shape (empty = scalar).
    pub shape: Vec<usize>,
}

impl IoSpec {
    /// Number of elements (1 for scalars).
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-lowered artifact: its HLO-text file and typed I/O contract.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// Artifact name (the `Runtime::load` key).
    pub name: String,
    /// Path of the HLO text file.
    pub file: PathBuf,
    /// Input specs, in call order.
    pub inputs: Vec<IoSpec>,
    /// Output specs, in tuple order.
    pub outputs: Vec<IoSpec>,
}

/// One target's segment of the SHiRA theta/idx vectors.
#[derive(Clone, Debug)]
pub struct ShiraSeg {
    /// Target tensor name.
    pub name: String,
    /// Target tensor shape (rows, cols).
    pub shape: (usize, usize),
    /// Sparse entries trained for this target.
    pub k: usize,
    /// Offset of this segment in the concatenated theta/idx vectors.
    pub off: usize,
    /// SHiRA-DoRA only: offset of the magnitude block.
    pub mag_off: Option<usize>,
    /// SHiRA-DoRA only: length of the magnitude block.
    pub mag_len: Option<usize>,
}

impl ShiraSeg {
    /// Elements of the target tensor (rows × cols) — the index space the
    /// segment's `k` sparse entries are drawn from.
    pub fn numel(&self) -> usize {
        self.shape.0 * self.shape.1
    }
}

/// One target's segment of the LoRA/DoRA theta vector.
#[derive(Clone, Debug)]
pub struct LoraSeg {
    /// Target tensor name.
    pub name: String,
    /// Target tensor shape (rows, cols).
    pub shape: (usize, usize),
    /// Adapter rank r.
    pub rank: usize,
    /// Offset of the A factor (rows × r) in theta.
    pub a_off: usize,
    /// Length of the A factor.
    pub a_len: usize,
    /// Offset of the B factor (r × cols) in theta.
    pub b_off: usize,
    /// Length of the B factor.
    pub b_len: usize,
    /// DoRA only: offset of the magnitude block.
    pub mag_off: Option<usize>,
    /// DoRA only: length of the magnitude block.
    pub mag_len: Option<usize>,
}

/// Dense layout entry (grad probe / full finetune).
#[derive(Clone, Debug)]
pub struct DenseSeg {
    /// Tensor name.
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Offset in the dense layout vector.
    pub off: usize,
    /// Element count in the dense layout vector.
    pub len: usize,
}

/// One model's manifest entry: parameter list, adapter layouts, and
/// named dimensions.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    /// Model name ("llama", "sd").
    pub name: String,
    /// (parameter name, shape) in artifact input order.
    pub params: Vec<(String, Vec<usize>)>,
    /// Adapter target tensor names.
    pub targets: Vec<String>,
    /// SHiRA theta/idx layout, one segment per target.
    pub shira: Vec<ShiraSeg>,
    /// LoRA theta layout.
    pub lora: Vec<LoraSeg>,
    /// DoRA theta layout (LoRA + magnitudes).
    pub dora: Vec<LoraSeg>,
    /// SHiRA-DoRA theta layout (sparse + magnitudes).
    pub shira_dora: Vec<ShiraSeg>,
    /// Dense grad-probe layout.
    pub probe: Vec<DenseSeg>,
    /// Dense full-finetune layout.
    pub full: Vec<DenseSeg>,
    /// Total theta length per adapter kind ("shira", "lora", ...).
    pub theta_len: HashMap<String, usize>,
    /// Named scalar dims (vocab / d_model / batch / seq_len / ...).
    pub extra: HashMap<String, usize>,
}

impl ModelMeta {
    /// Total base-model parameters.
    pub fn total_params(&self) -> usize {
        self.params.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }

    /// Look up a named dimension; panics when the manifest lacks it
    /// (a build-time contract violation, not a runtime condition).
    pub fn dim(&self, key: &str) -> usize {
        *self
            .extra
            .get(key)
            .unwrap_or_else(|| panic!("model {} missing dim {key}", self.name))
    }
}

/// Global adapter hyperparameters the artifacts were lowered with.
#[derive(Clone, Debug)]
pub struct AdapterMeta {
    /// SHiRA trainable fraction (paper: 1-2% of weights).
    pub shira_frac: f64,
    /// LoRA rank r.
    pub lora_rank: usize,
    /// LoRA alpha.
    pub lora_alpha: f64,
    /// Effective LoRA fuse scale (= alpha / rank).
    pub lora_scale: f64,
}

/// Typed view of `artifacts/manifest.json` — the contract between the
/// build-time python AOT pipeline and the rust runtime.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// The artifacts directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Every artifact by name.
    pub artifacts: HashMap<String, ArtifactMeta>,
    /// Every model by name.
    pub models: HashMap<String, ModelMeta>,
    /// Global adapter hyperparameters.
    pub adapter: AdapterMeta,
    /// Pallas demo kernel dimension (0 when absent).
    pub pallas_dim: usize,
    /// Pallas demo kernel sparse count (0 when absent).
    pub pallas_k: usize,
}

/// A malformed or unreadable manifest.
#[derive(Debug)]
pub struct ManifestError(
    /// What was wrong.
    pub String,
);

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "manifest error: {}", self.0)
    }
}

impl std::error::Error for ManifestError {}

fn err(msg: impl Into<String>) -> ManifestError {
    ManifestError(msg.into())
}

fn io_specs(j: &Json) -> Result<Vec<IoSpec>, ManifestError> {
    j.as_arr()
        .ok_or_else(|| err("inputs/outputs not an array"))?
        .iter()
        .map(|e| {
            Ok(IoSpec {
                name: e
                    .get("name")
                    .and_then(|x| x.as_str())
                    .ok_or_else(|| err("io name"))?
                    .to_string(),
                dtype: DType::parse(
                    e.get("dtype").and_then(|x| x.as_str()).unwrap_or("f32"),
                )
                .ok_or_else(|| err("bad dtype"))?,
                shape: e
                    .get("shape")
                    .and_then(|x| x.as_shape())
                    .ok_or_else(|| err("bad shape"))?,
            })
        })
        .collect()
}

fn shira_segs(j: &Json) -> Result<Vec<ShiraSeg>, ManifestError> {
    j.as_arr()
        .ok_or_else(|| err("shira layout not array"))?
        .iter()
        .map(|e| {
            let shape = e
                .get("shape")
                .and_then(|x| x.as_shape())
                .ok_or_else(|| err("seg shape"))?;
            Ok(ShiraSeg {
                name: e
                    .get("name")
                    .and_then(|x| x.as_str())
                    .ok_or_else(|| err("seg name"))?
                    .to_string(),
                shape: (shape[0], shape[1]),
                k: e.get("k").and_then(|x| x.as_usize()).ok_or_else(|| err("k"))?,
                off: e
                    .get("off")
                    .and_then(|x| x.as_usize())
                    .ok_or_else(|| err("off"))?,
                mag_off: e.get("mag_off").and_then(|x| x.as_usize()),
                mag_len: e.get("mag_len").and_then(|x| x.as_usize()),
            })
        })
        .collect()
}

fn lora_segs(j: &Json) -> Result<Vec<LoraSeg>, ManifestError> {
    j.as_arr()
        .ok_or_else(|| err("lora layout not array"))?
        .iter()
        .map(|e| {
            let shape = e
                .get("shape")
                .and_then(|x| x.as_shape())
                .ok_or_else(|| err("seg shape"))?;
            let g = |k: &str| e.get(k).and_then(|x| x.as_usize());
            Ok(LoraSeg {
                name: e
                    .get("name")
                    .and_then(|x| x.as_str())
                    .ok_or_else(|| err("seg name"))?
                    .to_string(),
                shape: (shape[0], shape[1]),
                rank: g("r").ok_or_else(|| err("r"))?,
                a_off: g("a_off").ok_or_else(|| err("a_off"))?,
                a_len: g("a_len").ok_or_else(|| err("a_len"))?,
                b_off: g("b_off").ok_or_else(|| err("b_off"))?,
                b_len: g("b_len").ok_or_else(|| err("b_len"))?,
                mag_off: g("mag_off"),
                mag_len: g("mag_len"),
            })
        })
        .collect()
}

fn dense_segs(j: &Json) -> Result<Vec<DenseSeg>, ManifestError> {
    j.as_arr()
        .ok_or_else(|| err("dense layout not array"))?
        .iter()
        .map(|e| {
            Ok(DenseSeg {
                name: e
                    .get("name")
                    .and_then(|x| x.as_str())
                    .ok_or_else(|| err("seg name"))?
                    .to_string(),
                shape: e
                    .get("shape")
                    .and_then(|x| x.as_shape())
                    .ok_or_else(|| err("seg shape"))?,
                off: e
                    .get("off")
                    .and_then(|x| x.as_usize())
                    .ok_or_else(|| err("off"))?,
                len: e
                    .get("len")
                    .and_then(|x| x.as_usize())
                    .ok_or_else(|| err("len"))?,
            })
        })
        .collect()
}

fn model_meta(name: &str, j: &Json) -> Result<ModelMeta, ManifestError> {
    let params = j
        .get("params")
        .and_then(|x| x.as_arr())
        .ok_or_else(|| err("params"))?
        .iter()
        .map(|p| {
            Ok((
                p.get("name")
                    .and_then(|x| x.as_str())
                    .ok_or_else(|| err("param name"))?
                    .to_string(),
                p.get("shape")
                    .and_then(|x| x.as_shape())
                    .ok_or_else(|| err("param shape"))?,
            ))
        })
        .collect::<Result<Vec<_>, ManifestError>>()?;
    let targets = j
        .get("targets")
        .and_then(|x| x.as_arr())
        .ok_or_else(|| err("targets"))?
        .iter()
        .map(|t| t.as_str().unwrap_or_default().to_string())
        .collect();
    let layout = j.get("layout").ok_or_else(|| err("layout"))?;
    let theta_len = j
        .get("theta_len")
        .and_then(|x| x.as_obj())
        .ok_or_else(|| err("theta_len"))?
        .iter()
        .filter_map(|(k, v)| v.as_usize().map(|n| (k.clone(), n)))
        .collect();
    let mut extra = HashMap::new();
    if let Some(obj) = j.as_obj() {
        for (k, v) in obj {
            if let Some(n) = v.as_usize() {
                if matches!(v, Json::Num(_)) {
                    extra.insert(k.clone(), n);
                }
            }
        }
    }
    Ok(ModelMeta {
        name: name.to_string(),
        params,
        targets,
        shira: layout
            .get("shira")
            .map(shira_segs)
            .transpose()?
            .unwrap_or_default(),
        lora: layout
            .get("lora")
            .map(lora_segs)
            .transpose()?
            .unwrap_or_default(),
        dora: layout
            .get("dora")
            .map(lora_segs)
            .transpose()?
            .unwrap_or_default(),
        shira_dora: layout
            .get("shira_dora")
            .map(shira_segs)
            .transpose()?
            .unwrap_or_default(),
        probe: layout
            .get("probe")
            .map(dense_segs)
            .transpose()?
            .unwrap_or_default(),
        full: layout
            .get("full")
            .map(dense_segs)
            .transpose()?
            .unwrap_or_default(),
        theta_len,
        extra,
    })
}

impl Manifest {
    /// Load and type-check `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, ManifestError> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| err(format!("read {}: {e}", path.display())))?;
        let j = json::parse(&text).map_err(|e| err(format!("parse: {e}")))?;

        let mut artifacts = HashMap::new();
        for (name, a) in j
            .get("artifacts")
            .and_then(|x| x.as_obj())
            .ok_or_else(|| err("artifacts"))?
        {
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    file: dir.join(
                        a.get("file")
                            .and_then(|x| x.as_str())
                            .ok_or_else(|| err("artifact file"))?,
                    ),
                    inputs: io_specs(a.get("inputs").ok_or_else(|| err("inputs"))?)?,
                    outputs: io_specs(a.get("outputs").ok_or_else(|| err("outputs"))?)?,
                },
            );
        }

        let mut models = HashMap::new();
        for (name, m) in j
            .get("models")
            .and_then(|x| x.as_obj())
            .ok_or_else(|| err("models"))?
        {
            models.insert(name.clone(), model_meta(name, m)?);
        }

        let ad = j.get("adapter").ok_or_else(|| err("adapter"))?;
        let adapter = AdapterMeta {
            shira_frac: ad
                .get("shira_frac")
                .and_then(|x| x.as_f64())
                .ok_or_else(|| err("shira_frac"))?,
            lora_rank: ad
                .get("lora_rank")
                .and_then(|x| x.as_usize())
                .ok_or_else(|| err("lora_rank"))?,
            lora_alpha: ad
                .get("lora_alpha")
                .and_then(|x| x.as_f64())
                .ok_or_else(|| err("lora_alpha"))?,
            lora_scale: ad
                .get("lora_scale")
                .and_then(|x| x.as_f64())
                .ok_or_else(|| err("lora_scale"))?,
        };

        Ok(Manifest {
            dir: dir.to_path_buf(),
            pallas_dim: j
                .path("pallas_demo.dim")
                .and_then(|x| x.as_usize())
                .unwrap_or(0),
            pallas_k: j
                .path("pallas_demo.k")
                .and_then(|x| x.as_usize())
                .unwrap_or(0),
            artifacts,
            models,
            adapter,
        })
    }

    /// Look up an artifact by name.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta, ManifestError> {
        self.artifacts
            .get(name)
            .ok_or_else(|| err(format!("unknown artifact {name}")))
    }

    /// Look up a model by name.
    pub fn model(&self, name: &str) -> Result<&ModelMeta, ManifestError> {
        self.models
            .get(name)
            .ok_or_else(|| err(format!("unknown model {name}")))
    }

    /// Default artifacts directory: $SHIRA_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("SHIRA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| {
                // tests run from the crate root; binaries may run elsewhere
                let local = PathBuf::from("artifacts");
                if local.join("manifest.json").exists() {
                    local
                } else {
                    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
                }
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(&dir).expect("manifest loads"))
        } else {
            None
        }
    }

    #[test]
    fn loads_and_exposes_models() {
        let Some(m) = manifest() else { return };
        let llama = m.model("llama").unwrap();
        assert!(llama.total_params() > 100_000);
        assert_eq!(llama.targets.len(), llama.shira.len());
        assert!(llama.dim("vocab") >= 64);
        let sd = m.model("sd").unwrap();
        assert!(!sd.shira.is_empty());
    }

    #[test]
    fn artifact_inputs_start_with_base_params() {
        let Some(m) = manifest() else { return };
        let llama = m.model("llama").unwrap();
        let fwd = m.artifact("llama_fwd").unwrap();
        for (i, (pname, pshape)) in llama.params.iter().enumerate() {
            assert_eq!(&fwd.inputs[i].name, pname);
            let want: Vec<usize> = if pshape.len() == 1 {
                pshape.clone()
            } else {
                pshape.clone()
            };
            assert_eq!(fwd.inputs[i].shape, want);
        }
        assert!(fwd.file.exists());
    }

    #[test]
    fn shira_layout_offsets_contiguous() {
        let Some(m) = manifest() else { return };
        let llama = m.model("llama").unwrap();
        let mut off = 0;
        for seg in &llama.shira {
            assert_eq!(seg.off, off);
            off += seg.k;
        }
        assert_eq!(off, llama.theta_len["shira"]);
    }

    #[test]
    fn unknown_names_error() {
        let Some(m) = manifest() else { return };
        assert!(m.artifact("nope").is_err());
        assert!(m.model("nope").is_err());
    }
}
