//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the rust hot path.  Python never runs here.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  All artifacts are lowered with
//! `return_tuple=True`, so outputs decompose via `Literal::to_tuple()`.

pub mod manifest;

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use manifest::{ArtifactMeta, DType, Manifest};

/// Host-side value marshalled into / out of an executable.
///
/// # Examples
///
/// ```
/// use shira::runtime::HostValue;
///
/// let v = HostValue::f32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
/// assert_eq!(v.shape(), &[2, 2]);
/// assert_eq!(v.numel(), 4);
/// assert_eq!(v.nbytes(), 16);
/// assert_eq!(HostValue::scalar_i32(7).as_i32(), &[7]);
/// ```
#[derive(Clone, Debug)]
pub enum HostValue {
    /// f32 data with its shape (row-major).
    F32(Vec<f32>, Vec<usize>),
    /// i32 data with its shape (row-major).
    I32(Vec<i32>, Vec<usize>),
}

impl HostValue {
    /// A shapeless f32 scalar.
    pub fn scalar_f32(x: f32) -> Self {
        HostValue::F32(vec![x], vec![])
    }

    /// A shapeless i32 scalar.
    pub fn scalar_i32(x: i32) -> Self {
        HostValue::I32(vec![x], vec![])
    }

    /// An f32 tensor (`data.len()` must equal the shape product).
    pub fn f32(data: Vec<f32>, shape: Vec<usize>) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        HostValue::F32(data, shape)
    }

    /// An i32 tensor (`data.len()` must equal the shape product).
    pub fn i32(data: Vec<i32>, shape: Vec<usize>) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        HostValue::I32(data, shape)
    }

    /// The value's shape (empty for scalars).
    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32(_, s) | HostValue::I32(_, s) => s,
        }
    }

    /// Number of elements.
    pub fn numel(&self) -> usize {
        match self {
            HostValue::F32(d, _) => d.len(),
            HostValue::I32(d, _) => d.len(),
        }
    }

    /// Host bytes held (both dtypes are 4 bytes wide).
    pub fn nbytes(&self) -> usize {
        self.numel() * 4
    }

    /// Borrow the f32 data (panics on an i32 value).
    pub fn as_f32(&self) -> &[f32] {
        match self {
            HostValue::F32(d, _) => d,
            _ => panic!("expected f32 value"),
        }
    }

    /// Borrow the i32 data (panics on an f32 value).
    pub fn as_i32(&self) -> &[i32] {
        match self {
            HostValue::I32(d, _) => d,
            _ => panic!("expected i32 value"),
        }
    }

    /// Take the f32 data (panics on an i32 value).
    pub fn into_f32(self) -> Vec<f32> {
        match self {
            HostValue::F32(d, _) => d,
            _ => panic!("expected f32 value"),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            HostValue::F32(data, shape) => {
                let l = xla::Literal::vec1(data.as_slice());
                let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
                l.reshape(&dims)?
            }
            HostValue::I32(data, shape) => {
                let l = xla::Literal::vec1(data.as_slice());
                let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
                l.reshape(&dims)?
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal, dtype: DType, shape: &[usize]) -> Result<Self> {
        Ok(match dtype {
            DType::F32 => HostValue::F32(lit.to_vec::<f32>()?, shape.to_vec()),
            DType::I32 => HostValue::I32(lit.to_vec::<i32>()?, shape.to_vec()),
        })
    }
}

/// One compiled artifact.
pub struct Executable {
    /// The artifact's manifest entry (name, input/output specs).
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host values; validates arity/shape against the manifest.
    pub fn run(&self, inputs: &[HostValue]) -> Result<Vec<HostValue>> {
        if inputs.len() != self.meta.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            ));
        }
        for (v, spec) in inputs.iter().zip(self.meta.inputs.iter()) {
            if v.numel() != spec.numel() {
                return Err(anyhow!(
                    "{}: input '{}' expects {:?} ({} elems), got {} elems",
                    self.meta.name,
                    spec.name,
                    spec.shape,
                    spec.numel(),
                    v.numel()
                ));
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != self.meta.outputs.len() {
            return Err(anyhow!(
                "{}: expected {} outputs, got {}",
                self.meta.name,
                self.meta.outputs.len(),
                parts.len()
            ));
        }
        parts
            .iter()
            .zip(self.meta.outputs.iter())
            .map(|(lit, spec)| HostValue::from_literal(lit, spec.dtype, &spec.shape))
            .collect()
    }
}

/// The PJRT runtime: one CPU client + lazily compiled artifact cache.
pub struct Runtime {
    /// The typed view of `artifacts/manifest.json`.
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    /// Runtime over an artifacts directory (must contain
    /// `manifest.json` and the HLO-text files it names).
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)
            .map_err(|e| anyhow!("loading manifest: {e}"))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            manifest,
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Runtime over [`Manifest::default_dir`] (`$SHIRA_ARTIFACTS` or
    /// `./artifacts`).
    pub fn with_default_artifacts() -> Result<Self> {
        Runtime::new(&Manifest::default_dir())
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for `name`.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(std::sync::Arc::clone(e));
        }
        let meta = self
            .manifest
            .artifact(name)
            .map_err(|e| anyhow!("{e}"))?
            .clone();
        let proto = xla::HloModuleProto::from_text_file(&meta.file)
            .with_context(|| format!("parsing HLO text {}", meta.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let entry = std::sync::Arc::new(Executable { meta, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), std::sync::Arc::clone(&entry));
        Ok(entry)
    }

    /// One-shot convenience.
    pub fn run(&self, name: &str, inputs: &[HostValue]) -> Result<Vec<HostValue>> {
        self.load(name)?.run(inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            Some(Runtime::new(&dir).expect("runtime"))
        } else {
            None
        }
    }

    #[test]
    fn host_value_accessors() {
        let v = HostValue::f32(vec![1.0, 2.0], vec![2]);
        assert_eq!(v.numel(), 2);
        assert_eq!(v.nbytes(), 8);
        assert_eq!(v.as_f32(), &[1.0, 2.0]);
        let s = HostValue::scalar_i32(7);
        assert_eq!(s.shape(), &[] as &[usize]);
        assert_eq!(s.as_i32(), &[7]);
    }

    #[test]
    fn sd_fwd_runs_and_is_deterministic() {
        let Some(rt) = runtime() else { return };
        let sd = rt.manifest.model("sd").unwrap().clone();
        let mut inputs = Vec::new();
        let rng = crate::util::rng::Rng::new(3);
        for (name, shape) in &sd.params {
            let numel: usize = shape.iter().product();
            let mut data = vec![0.0f32; numel];
            rng.stream(name).fill_normal(&mut data, 0.0, 0.1);
            inputs.push(HostValue::f32(data, shape.clone()));
        }
        let b = sd.dim("batch");
        let dz = sd.dim("d_z");
        let z: Vec<f32> = (0..b * dz).map(|i| (i as f32 * 0.01).sin()).collect();
        inputs.push(HostValue::f32(z, vec![b, dz]));
        let out1 = rt.run("sd_fwd", &inputs).unwrap();
        let out2 = rt.run("sd_fwd", &inputs).unwrap();
        assert_eq!(out1.len(), 1);
        assert_eq!(out1[0].shape(), &[b, sd.dim("d_img")]);
        assert_eq!(out1[0].as_f32(), out2[0].as_f32());
        assert!(out1[0].as_f32().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn arity_mismatch_is_error() {
        let Some(rt) = runtime() else { return };
        assert!(rt.run("sd_fwd", &[]).is_err());
    }

    #[test]
    fn apply_shira_artifact_matches_native_scatter() {
        // The L1 pallas kernel (inside the artifact) and the native rust
        // ScatterEngine must agree — the cross-layer correctness check.
        let Some(rt) = runtime() else { return };
        let d = rt.manifest.pallas_dim;
        let k = rt.manifest.pallas_k;
        if d == 0 {
            return;
        }
        let mut rng = crate::util::rng::Rng::new(9);
        let mut w = vec![0.0f32; d * d];
        rng.fill_normal(&mut w, 0.0, 1.0);
        let idx = rng.sample_indices(d * d, k);
        let mut vals = vec![0.0f32; k];
        rng.fill_normal(&mut vals, 0.0, 1.0);

        let out = rt
            .run(
                "apply_shira",
                &[
                    HostValue::f32(w.clone(), vec![d, d]),
                    HostValue::i32(idx.iter().map(|&i| i as i32).collect(), vec![k]),
                    HostValue::f32(vals.clone(), vec![k]),
                ],
            )
            .unwrap();
        let got = out[0].as_f32();

        let mut want = w.clone();
        for (j, &i) in idx.iter().enumerate() {
            want[i as usize] = vals[j];
        }
        assert_eq!(got, want.as_slice());
    }
}
