//! Offline drop-in subset of the `anyhow` error-handling crate.
//!
//! The build environment has no registry access, so the subset of anyhow
//! this codebase actually uses — `Error`, `Result`, the `anyhow!` macro,
//! and the `Context` extension trait — is vendored here as a path crate.
//! Semantics match upstream for that subset: `Error` is a cheap opaque
//! error value with a context chain, any `std::error::Error` converts into
//! it via `?`, and `{:#}` formatting prints the full chain.

use std::fmt;

/// Opaque error: a message plus the contexts layered on top of it.
/// Deliberately does NOT implement `std::error::Error`, mirroring upstream
/// anyhow (that keeps the blanket `From<E: std::error::Error>` coherent).
pub struct Error {
    /// Innermost message first; contexts are pushed on the outside.
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.push(context.to_string());
        self
    }

    /// Outermost-first iterator over the context chain (like
    /// `anyhow::Error::chain`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().rev().map(|s| s.as_str())
    }

    /// The outermost message.
    fn top(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain, outermost first.
            for (i, part) in self.chain().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{part}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.top())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.top())?;
        let causes: Vec<&str> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E>
    for std::result::Result<T, E>
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or any
/// `Display` value — same surface as `anyhow::anyhow!`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// `if !cond { bail!(..) }`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let n = 3;
        let b = anyhow!("value {n}");
        assert_eq!(b.to_string(), "value 3");
        let c = anyhow!("{} and {}", 1, 2);
        assert_eq!(c.to_string(), "1 and 2");
        let d = anyhow!(String::from("owned"));
        assert_eq!(d.to_string(), "owned");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "missing file");
    }

    #[test]
    fn context_chain_formats() {
        let e: Result<()> = Err(io_err());
        let e = e.context("opening manifest").unwrap_err();
        assert_eq!(e.to_string(), "opening manifest");
        assert_eq!(format!("{e:#}"), "opening manifest: missing file");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn with_context_and_option() {
        let e: Result<()> = Err(io_err());
        let e = e.with_context(|| format!("step {}", 7)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 7: missing file");
        let none: Option<u32> = None;
        assert_eq!(none.context("empty").unwrap_err().to_string(), "empty");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 7 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky");
        assert_eq!(f(12).unwrap_err().to_string(), "too big: 12");
    }
}
