//! API-compatible stub of the `xla-rs` PJRT bindings.
//!
//! The offline build environment has neither the XLA C API shared library
//! nor registry access, so this path crate provides the exact surface the
//! runtime layer compiles against.  Host-side `Literal` plumbing is fully
//! functional (so marshalling code is real and testable); the device-side
//! entry points (`PjRtClient::cpu`, `HloModuleProto::from_text_file`)
//! return a descriptive error, which the runtime and every artifact-gated
//! test already treat as "artifacts unavailable — skip".
//!
//! Swap this crate's path in `rust/Cargo.toml` for the real xla-rs to run
//! against a PJRT plugin; no source change in `shira` is needed.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} requires the PJRT runtime, which is not present in this \
         build (vendored stub; see rust/vendor/xla)"
    ))
}

/// Element types the host marshalling layer supports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    I32,
}

/// Sealed-ish conversion trait for host buffers.
pub trait NativeType: Copy + Sized {
    const TY: ElementType;
    fn to_bytes(data: &[Self]) -> Vec<u8>;
    fn from_bytes(bytes: &[u8]) -> Vec<Self>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn to_bytes(data: &[Self]) -> Vec<u8> {
        data.iter().flat_map(|x| x.to_le_bytes()).collect()
    }
    fn from_bytes(bytes: &[u8]) -> Vec<Self> {
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::I32;
    fn to_bytes(data: &[Self]) -> Vec<u8> {
        data.iter().flat_map(|x| x.to_le_bytes()).collect()
    }
    fn from_bytes(bytes: &[u8]) -> Vec<Self> {
        bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }
}

/// Host-side literal: raw bytes + element type + dims.  Functional.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    bytes: Vec<u8>,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            ty: T::TY,
            bytes: T::to_bytes(data),
            dims: vec![data.len() as i64],
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have: i64 = self.dims.iter().product();
        if want != have {
            return Err(Error(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Literal {
            ty: self.ty,
            bytes: self.bytes.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::TY != self.ty {
            return Err(Error(format!(
                "element type mismatch: literal is {:?}",
                self.ty
            )));
        }
        Ok(T::from_bytes(&self.bytes))
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product::<i64>() as usize
    }

    /// Tuple decomposition — stub literals are never tuples.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple on a device result"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, -2.5, 3.25]);
        assert_eq!(l.element_count(), 3);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 3.25]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_reshape_checks_count() {
        let l = Literal::vec1(&[1i32, 2, 3, 4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.element_count(), 4);
        assert_eq!(r.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
        assert!(l.reshape(&[3]).is_err());
    }

    #[test]
    fn device_paths_report_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("stub"));
    }
}
