//! Vision-side walk-through (paper §4.2, Table 1 / Figs 1, 4, 6, 7 proxy):
//! style-transfer adapters on the nanosd generator.
//!
//! Trains a bluefire and a paintings adapter (SHiRA-SNIP + LoRA baseline),
//! scores single-style generation, the α knob, held-out "koala" concepts,
//! and dual-style fusion with the SPS (HPSv2-proxy) metric.
//!
//! Run: `cargo run --release --example style_transfer [--fast]`

use shira::adapter::mask::MaskStrategy;
use shira::config::RunConfig;
use shira::coordinator::fusion;
use shira::coordinator::switch::SwitchEngine;
use shira::data::style::{Style, StyleDataset};
use shira::runtime::{HostValue, Runtime};
use shira::train::eval::{eval_style, eval_style_multi};
use shira::train::schedule::Schedule;
use shira::train::{Trainer, TrainKind};
use shira::util::cli::Args;
use shira::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    shira::util::log::init();
    let args = Args::from_env(&[]).map_err(|e| anyhow::anyhow!("{e}"))?;
    let cfg = RunConfig::from_args(&args).map_err(|e| anyhow::anyhow!(e))?;
    let rt = match Runtime::with_default_artifacts() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping style_transfer: artifacts not built (run `make artifacts`): {e}");
            return Ok(());
        }
    };
    let world = shira::repro::style_world(&rt, &cfg);
    let base = shira::repro::ensure_sd_base(&rt, &cfg, &world)?;
    let meta = rt.manifest.model("sd").unwrap();
    let batch = meta.dim("batch");

    let mut shira_adapters = Vec::new();
    let mut lora_adapters = Vec::new();
    for (i, style) in [Style::Bluefire, Style::Paintings].into_iter().enumerate() {
        let trainer = Trainer::new(&rt, "sd", base.clone())?;
        let ds = StyleDataset::new(world.clone(), style, cfg.seed);
        let dz = world.d_z;
        let dimg = world.d_img;
        let mk_data = |ds: &StyleDataset| {
            let ds = StyleDataset::new(ds.world.clone(), ds.style, cfg.seed);
            move |_s: usize, rng: &mut Rng| {
                let (z, t) = ds.train_batch(batch, rng);
                vec![
                    HostValue::f32(z, vec![batch, dz]),
                    HostValue::f32(t, vec![batch, dimg]),
                ]
            }
        };
        let mut data = mk_data(&ds);
        let out = trainer.train(
            TrainKind::Shira(MaskStrategy::Snip),
            cfg.adapter_steps,
            Schedule::Cosine { lr: cfg.lr_shira as f32 },
            &mut data,
            cfg.seed ^ (400 + i as u64),
        )?;
        println!(
            "SHiRA {} adapter: loss {:.4} -> {:.4} ({} nnz)",
            style.name(),
            out.first_loss(),
            out.last_loss(),
            out.trainable_params
        );
        shira_adapters.push((style, trainer.export_shira(&out, style.name(), MaskStrategy::Snip)));

        let mut data = mk_data(&ds);
        let out = trainer.train(
            TrainKind::Lora,
            cfg.adapter_steps,
            Schedule::Cosine { lr: cfg.lr_lora as f32 },
            &mut data,
            cfg.seed ^ (500 + i as u64),
        )?;
        lora_adapters.push((style, trainer.export_lora(&out, style.name())));
    }

    // ---- single-style quality (seen + unseen concepts) -------------------
    println!("\n| adapter | SPS seen | SPS unseen (koala) |");
    println!("|---|---|---|");
    for (style, adapter) in &shira_adapters {
        let mut w = base.clone();
        SwitchEngine::new().switch_to_shira(&mut w, adapter, 1.0);
        let seen = eval_style(&rt, &w, &world, *style, 1.0,
                              cfg.style_eval_batches, false, cfg.seed)?;
        let unseen = eval_style(&rt, &w, &world, *style, 1.0,
                                cfg.style_eval_batches, true, cfg.seed)?;
        println!("| SHiRA {} | {seen:.1} | {unseen:.1} |", style.name());
    }
    for (style, adapter) in &lora_adapters {
        let mut w = base.clone();
        SwitchEngine::new().switch_to_lora(&mut w, adapter);
        let seen = eval_style(&rt, &w, &world, *style, 1.0,
                              cfg.style_eval_batches, false, cfg.seed)?;
        let unseen = eval_style(&rt, &w, &world, *style, 1.0,
                                cfg.style_eval_batches, true, cfg.seed)?;
        println!("| LoRA {} | {seen:.1} | {unseen:.1} |", style.name());
    }

    // ---- the α knob (Fig. 6) ---------------------------------------------
    let (style, adapter) = &shira_adapters[0];
    println!("\nα sweep on {} (SPS vs α-matched target):", style.name());
    for alpha in [0.0f32, 0.5, 1.0, 1.5, 2.0] {
        let mut w = base.clone();
        SwitchEngine::new().switch_to_shira(&mut w, adapter, alpha);
        let s = eval_style(&rt, &w, &world, *style, alpha,
                           cfg.style_eval_batches, false, cfg.seed)?;
        println!("  α={alpha:3.1}  SPS {s:.1}");
    }

    // ---- dual-style fusion (Figs 1/4/7) ------------------------------------
    let fused = fusion::fuse_shira(
        &[&shira_adapters[0].1, &shira_adapters[1].1],
        "bluefire+paintings",
    )?;
    let mut wf = base.clone();
    SwitchEngine::new().switch_to_shira(&mut wf, &fused, 0.5);
    let shira_multi = eval_style_multi(&rt, &wf, &world,
                                       cfg.style_eval_batches, cfg.seed)?;
    let mut lw = base.clone();
    for (_, l) in &lora_adapters {
        for t in &l.tensors {
            lw.get_mut(&t.target).add_outer_product(&t.a, &t.b, 0.5 * l.scale);
        }
    }
    let lora_multi = eval_style_multi(&rt, &lw, &world, cfg.style_eval_batches, cfg.seed)?;
    println!("\ndual-style generation (both concepts at once):");
    println!("  SHiRA naive fusion : SPS {shira_multi:.1}");
    println!("  LoRA fused products: SPS {lora_multi:.1}");
    println!("paper shape: SHiRA retains both styles; LoRA loses concepts.");
    Ok(())
}
