//! Quickstart: the full SHiRA lifecycle in ~80 lines.
//!
//! 1. load the AOT runtime (built by `make artifacts`),
//! 2. finetune a SHiRA adapter (1-2% of weights) on a task,
//! 3. save it to the portable `.shira` format,
//! 4. load it back and rapid-switch it onto the base weights,
//! 5. evaluate fused vs base accuracy, and revert bit-exactly.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).

use shira::adapter::io;
use shira::adapter::mask::MaskStrategy;
use shira::config::RunConfig;
use shira::coordinator::switch::SwitchEngine;
use shira::data::tasks::Task;
use shira::runtime::{HostValue, Runtime};
use shira::train::eval::eval_task;
use shira::train::schedule::Schedule;
use shira::train::{Trainer, TrainKind};
use shira::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    shira::util::log::init();
    let cfg = RunConfig::fast();
    let rt = match Runtime::with_default_artifacts() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping quickstart: artifacts not built (run `make artifacts`): {e}");
            return Ok(());
        }
    };
    println!("PJRT platform: {}", rt.platform());

    // -- base model (pretrained + cached under artifacts/checkpoints) ----
    let base = shira::repro::ensure_llama_base(&rt, &cfg, "llama_a")?;
    println!("base model: {} params", base.total_params());

    // -- train a SHiRA-WM adapter on one task -----------------------------
    let task = Task::ArcEasy;
    let trainer = Trainer::new(&rt, "llama", base.clone())?;
    let (b, t) = (trainer.model.dim("batch"), trainer.model.dim("seq_len"));
    let seed = cfg.seed;
    let mut data = move |_s: usize, rng: &mut Rng| {
        let batch =
            shira::data::tasks::mixture_batch(&[task], b, t, seed, rng);
        vec![
            HostValue::i32(batch.x, vec![b, t]),
            HostValue::i32(batch.y, vec![b, t]),
            HostValue::f32(batch.mask, vec![b, t]),
        ]
    };
    let out = trainer.train(
        TrainKind::Shira(MaskStrategy::WeightMagnitude),
        cfg.adapter_steps,
        Schedule::Linear { lr: cfg.lr_shira as f32, floor_frac: 0.1 },
        &mut data,
        cfg.seed,
    )?;
    println!(
        "trained {}: loss {:.3} -> {:.3} ({} trainable = {:.2}% of model)",
        out.kind_label,
        out.first_loss(),
        out.last_loss(),
        out.trainable_params,
        100.0 * out.trainable_params as f64 / base.total_params() as f64,
    );

    // -- export / save / load ---------------------------------------------
    let adapter = trainer.export_shira(&out, "arc_easy", MaskStrategy::WeightMagnitude);
    let path = std::env::temp_dir().join("quickstart.shira");
    io::save_shira(&path, &adapter).map_err(|e| anyhow::anyhow!("{e}"))?;
    let loaded = io::load_shira(&path).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "saved + loaded adapter '{}': {} nnz, {} bytes on disk",
        loaded.name,
        loaded.param_count(),
        std::fs::metadata(&path)?.len()
    );

    // -- rapid switch + evaluate ------------------------------------------
    let base_acc = 100.0 * eval_task(&rt, &base, task, cfg.eval_examples, cfg.seed)?;
    let mut weights = base.clone();
    let mut engine = SwitchEngine::new();
    let timing = engine.switch_to_shira(&mut weights, &loaded, 1.0);
    let fused_acc =
        100.0 * eval_task(&rt, &weights, task, cfg.eval_examples, cfg.seed)?;
    engine.revert(&mut weights);
    assert!(weights.bit_equal(&base), "revert must be exact");
    println!(
        "accuracy on {}: base {base_acc:.1}% -> adapted {fused_acc:.1}% \
         (switch applied in {:.0}us, revert bit-exact)",
        task.name(),
        timing.fuse_us
    );
    Ok(())
}
