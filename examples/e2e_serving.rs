//! END-TO-END DRIVER (DESIGN.md §6, EXPERIMENTS.md §E2E): proves all three
//! layers compose on a real workload.
//!
//! 1. pretrains the nanollama base via the AOT `llama_train_full` artifact,
//!    logging the loss curve (L2 graphs through the L3 runtime);
//! 2. finetunes THREE per-task SHiRA adapters + one LoRA baseline adapter
//!    (the L1 scatter semantics inside the train-step graphs);
//! 3. evaluates each adapter fused vs the base (accuracy lift);
//! 4. serves request traces through the unified `Selection` API: one
//!    SHiRA server handles a trace mixing base, single-adapter and
//!    fused-set selections per-request; LoRA servers run the fuse and
//!    unfused baselines — reporting throughput / p99 / switch overhead.
//!
//! Run: `cargo run --release --example e2e_serving [--fast]`

use shira::adapter::mask::MaskStrategy;
use shira::config::RunConfig;
use shira::coordinator::selection::Selection;
use shira::coordinator::server::Server;
use shira::coordinator::switch::SwitchEngine;
use shira::data::tasks::Task;
use shira::data::trace::{generate_trace, mixed_selections, switch_count, TracePattern};
use shira::runtime::{HostValue, Runtime};
use shira::train::eval::eval_task;
use shira::train::schedule::Schedule;
use shira::train::{Trainer, TrainKind};
use shira::util::cli::Args;
use shira::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    shira::util::log::init();
    let args = Args::from_env(&[]).map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut cfg = RunConfig::from_args(&args).map_err(|e| anyhow::anyhow!(e))?;
    if !args.has("steps") {
        // the E2E driver trains a bit longer than the repro defaults
        cfg.adapter_steps = if args.has("fast") { 40 } else { 300 };
    }
    let rt = match Runtime::with_default_artifacts() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping e2e_serving: artifacts not built (run `make artifacts`): {e}");
            return Ok(());
        }
    };
    println!("=== E2E: layers L1(Pallas)+L2(JAX)+L3(rust) on {} ===", rt.platform());

    // ---- phase 1: pretrain base (loss curve logged) ----------------------
    let meta = rt.manifest.model("llama").unwrap().clone();
    let (b, t, v) = (meta.dim("batch"), meta.dim("seq_len"), meta.dim("vocab"));
    let base = shira::model::weights::WeightStore::init(&meta.params, cfg.seed);
    let mut trainer = Trainer::new(&rt, "llama", base)?;
    let table_seed = cfg.seed ^ 0x5EED;
    let mut data = move |_s: usize, rng: &mut Rng| {
        let batch = if rng.below(2) == 0 {
            shira::data::tasks::pretrain_batch(v, b, t, rng)
        } else {
            shira::data::tasks::mixture_batch(
                &shira::data::tasks::ALL_TASKS, b, t, table_seed, rng,
            )
        };
        vec![
            HostValue::i32(batch.x, vec![b, t]),
            HostValue::i32(batch.y, vec![b, t]),
            HostValue::f32(batch.mask, vec![b, t]),
        ]
    };
    let steps = cfg.pretrain_steps;
    let out = trainer.train(
        TrainKind::Full,
        steps,
        Schedule::Cosine { lr: 3e-3 },
        &mut data,
        cfg.seed,
    )?;
    println!("\n-- pretraining loss curve ({} steps, {:.2} steps/s) --", steps, out.steps_per_sec);
    let stride = (steps / 12).max(1);
    for (i, loss) in out.losses.iter().enumerate() {
        if i % stride == 0 || i == steps - 1 {
            println!("  step {i:4}  loss {loss:.4}");
        }
    }
    trainer.absorb_full_theta(&out.theta);
    let base = trainer.base.clone();

    // ---- phase 2: per-task adapters --------------------------------------
    let tasks = [Task::BoolQ, Task::Piqa, Task::ArcEasy];
    let mut adapters = Vec::new();
    for (i, &task) in tasks.iter().enumerate() {
        let trainer = Trainer::new(&rt, "llama", base.clone())?;
        let seed = cfg.seed;
        let mut data = move |_s: usize, rng: &mut Rng| {
            let batch = shira::data::tasks::mixture_batch(&[task], b, t, seed, rng);
            vec![
                HostValue::i32(batch.x, vec![b, t]),
                HostValue::i32(batch.y, vec![b, t]),
                HostValue::f32(batch.mask, vec![b, t]),
            ]
        };
        let out = trainer.train(
            TrainKind::Shira(MaskStrategy::Snip),
            cfg.adapter_steps,
            Schedule::Linear { lr: cfg.lr_shira as f32, floor_frac: 0.1 },
            &mut data,
            cfg.seed ^ (100 + i as u64),
        )?;
        let adapter = trainer.export_shira(&out, task.name(), MaskStrategy::Snip);
        println!(
            "adapter '{}': loss {:.3}->{:.3}, nnz={} ({} bytes)",
            adapter.name,
            out.first_loss(),
            out.last_loss(),
            adapter.param_count(),
            adapter.nbytes()
        );
        adapters.push((task, adapter));
    }

    // ---- phase 3: fused accuracy lift ------------------------------------
    println!("\n-- accuracy: base vs adapted (fused mode) --");
    println!("| task | base | +SHiRA | lift |");
    println!("|---|---|---|---|");
    for (task, adapter) in &adapters {
        let base_acc = 100.0 * eval_task(&rt, &base, *task, cfg.eval_examples, cfg.seed)?;
        let mut weights = base.clone();
        SwitchEngine::new().switch_to_shira(&mut weights, adapter, 1.0);
        let acc = 100.0 * eval_task(&rt, &weights, *task, cfg.eval_examples, cfg.seed)?;
        println!(
            "| {} | {base_acc:.1}% | {acc:.1}% | {:+.1} |",
            task.name(),
            acc - base_acc
        );
    }

    // ---- phase 4: serve through the unified Selection API -----------------
    // One SHiRA trace mixing base, singles and rotating fused sets — all
    // routed per-request through ONE server — plus LoRA fuse/unfused
    // baselines over the same request pattern.
    let names: Vec<String> = adapters.iter().map(|(_, a)| a.name.clone()).collect();
    let mixed_sels = mixed_selections(&names);
    let trace = generate_trace(
        &mixed_sels,
        cfg.trace_len.max(60),
        TracePattern::Bursty { burst: 6 },
        2e4,
        cfg.seed,
    );
    println!(
        "\n-- serving {} requests ({} trace switches) --",
        trace.len(),
        switch_count(&trace)
    );
    // LoRA baseline adapter zoo for the fuse/unfused policies
    let mut lora_adapters = Vec::new();
    for (i, (task, _)) in adapters.iter().enumerate() {
        let trainer = Trainer::new(&rt, "llama", base.clone())?;
        let task = *task;
        let seed = cfg.seed;
        let mut data = move |_s: usize, rng: &mut Rng| {
            let batch = shira::data::tasks::mixture_batch(&[task], b, t, seed, rng);
            vec![
                HostValue::i32(batch.x, vec![b, t]),
                HostValue::i32(batch.y, vec![b, t]),
                HostValue::f32(batch.mask, vec![b, t]),
            ]
        };
        let out = trainer.train(
            TrainKind::Lora,
            cfg.adapter_steps.min(60), // baseline zoo only needs to exist
            Schedule::Linear { lr: cfg.lr_lora as f32, floor_frac: 0.1 },
            &mut data,
            cfg.seed ^ (200 + i as u64),
        )?;
        lora_adapters.push(trainer.export_lora(&out, task.name()));
    }
    println!("| mode | switches | t/f/fused | mean switch (us) | mean exec (us) | p99 (us) | req/s |");
    println!("|---|---|---|---|---|---|---|");
    // SHiRA: ONE server routes the mixed base/single/set trace.
    {
        let mut server = Server::builder(&rt, base.clone())
            .model("llama")
            .cache_bytes(cfg.cache_bytes)
            .build()?;
        for (_, a) in &adapters {
            server.store.add_shira(a);
        }
        let rep = server.run_trace(&trace)?;
        println!(
            "| shira mixed ({}b/{}s/{}set) | {} | {}/{}/{} | {:.1} | {:.1} | {:.0} | {:.1} |",
            rep.base_requests,
            rep.single_requests,
            rep.set_requests,
            rep.switches,
            rep.transitions,
            rep.fallbacks,
            rep.fused_switches,
            rep.mean_switch_us,
            rep.mean_exec_us,
            rep.p99_latency_us,
            rep.throughput_rps
        );
        // The same server keeps serving: revert restores base exactly.
        server.revert_all();
        assert!(server.weights().bit_equal(&base), "revert_all must be exact");
    }
    // LoRA baselines over single-adapter selections of the same names.
    let lora_trace = generate_trace(
        &Selection::singles(&names),
        cfg.trace_len.max(60),
        TracePattern::Bursty { burst: 6 },
        2e4,
        cfg.seed,
    );
    for unfused in [false, true] {
        let mut server = Server::builder(&rt, base.clone())
            .model("llama")
            .cache_bytes(cfg.cache_bytes)
            .unfused_lora(unfused)
            .build()?;
        for a in &lora_adapters {
            server.store.add_lora(a);
        }
        let rep = server.run_trace(&lora_trace)?;
        println!(
            "| {} | {} | {}/{}/{} | {:.1} | {:.1} | {:.0} | {:.1} |",
            if unfused { "lora-unfused" } else { "lora-fuse" },
            rep.switches,
            rep.transitions,
            rep.fallbacks,
            rep.fused_switches,
            rep.mean_switch_us,
            rep.mean_exec_us,
            rep.p99_latency_us,
            rep.throughput_rps
        );
    }
    println!("\nE2E complete: pretraining, adapter finetuning, fused eval and");
    println!("Selection-routed serving all ran through the AOT artifacts.");
    Ok(())
}
