//! Multi-adapter fusion walk-through (paper §3.2 + Table 4): train
//! independent per-task adapters, fuse them naively, measure the concept
//! retention of the fused adapter, and inspect the interference stats that
//! explain WHY sparse fusion works.
//!
//! Run: `cargo run --release --example multi_adapter_fusion [--fast]`

use shira::adapter::mask::MaskStrategy;
use shira::config::RunConfig;
use shira::coordinator::fusion;
use shira::coordinator::switch::SwitchEngine;
use shira::data::tasks::Task;
use shira::runtime::{HostValue, Runtime};
use shira::train::eval::eval_task;
use shira::train::schedule::Schedule;
use shira::train::{Trainer, TrainKind};
use shira::util::cli::Args;
use shira::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    shira::util::log::init();
    let args = Args::from_env(&[]).map_err(|e| anyhow::anyhow!("{e}"))?;
    let cfg = RunConfig::from_args(&args).map_err(|e| anyhow::anyhow!(e))?;
    let rt = Runtime::with_default_artifacts()?;
    let base = shira::repro::ensure_llama_base(&rt, &cfg, "llama_a")?;
    let tasks = [Task::BoolQ, Task::Piqa, Task::ArcEasy];
    let meta = rt.manifest.model("llama").unwrap();
    let (b, t) = (meta.dim("batch"), meta.dim("seq_len"));

    // ---- independent adapters ------------------------------------------
    let mut adapters = Vec::new();
    for (i, &task) in tasks.iter().enumerate() {
        let trainer = Trainer::new(&rt, "llama", base.clone())?;
        let seed = cfg.seed;
        let mut data = move |_s: usize, rng: &mut Rng| {
            let batch = shira::data::tasks::mixture_batch(&[task], b, t, seed, rng);
            vec![
                HostValue::i32(batch.x, vec![b, t]),
                HostValue::i32(batch.y, vec![b, t]),
                HostValue::f32(batch.mask, vec![b, t]),
            ]
        };
        let out = trainer.train(
            TrainKind::Shira(MaskStrategy::WeightMagnitude),
            cfg.adapter_steps,
            Schedule::Linear { lr: cfg.lr_shira as f32, floor_frac: 0.1 },
            &mut data,
            cfg.seed ^ (300 + i as u64),
        )?;
        adapters.push(trainer.export_shira(&out, task.name(), MaskStrategy::WeightMagnitude));
    }

    // ---- interference analysis ------------------------------------------
    let refs: Vec<&shira::adapter::ShiraAdapter> = adapters.iter().collect();
    let report = fusion::analyze_shira(&refs);
    println!("interference across {} independently trained adapters:", refs.len());
    println!("  mean support overlap : {:.4}", report.mean_overlap);
    println!("  mean A1ᵀA2 density   : {:.4}  (LoRA fused products: 1.0)", report.mean_ata_density);
    println!("  colliding entries    : {}", report.collisions);

    // ---- naive fusion + accuracy retention -------------------------------
    let fused = fusion::fuse_shira(&refs, "boolq+piqa+arc_e");
    println!(
        "\nfused adapter: {} nnz ({} bytes) — naive sparse addition, no retraining",
        fused.param_count(),
        fused.nbytes()
    );
    println!("\n| task | base | own adapter | fused (3 concepts) | drop vs own |");
    println!("|---|---|---|---|---|");
    let mut single_avg = 0.0;
    let mut multi_avg = 0.0;
    for (task, adapter) in tasks.iter().zip(adapters.iter()) {
        let base_acc = 100.0 * eval_task(&rt, &base, *task, cfg.eval_examples, cfg.seed)?;
        let mut e1 = SwitchEngine::new(base.clone());
        e1.switch_to_shira(adapter, 1.0);
        let own = 100.0 * eval_task(&rt, &e1.weights, *task, cfg.eval_examples, cfg.seed)?;
        let mut e2 = SwitchEngine::new(base.clone());
        e2.switch_to_shira(&fused, 1.0);
        let multi = 100.0 * eval_task(&rt, &e2.weights, *task, cfg.eval_examples, cfg.seed)?;
        println!(
            "| {} | {base_acc:.1}% | {own:.1}% | {multi:.1}% | {:.1} |",
            task.name(),
            own - multi
        );
        single_avg += own / tasks.len() as f64;
        multi_avg += multi / tasks.len() as f64;
    }
    println!(
        "\naverage: single {single_avg:.1}% -> fused {multi_avg:.1}% (%Drop = {:.2})",
        single_avg - multi_avg
    );
    println!("paper shape (Table 4): SHiRA's %Drop stays small because sparse");
    println!("supports barely collide; dense LoRA fusion interferes everywhere.");
    Ok(())
}
