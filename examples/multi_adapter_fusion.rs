//! Multi-adapter fusion walk-through (paper §3.2 + Table 4): train
//! independent per-task adapters, fuse them naively, measure the concept
//! retention of the fused adapter, inspect the interference stats that
//! explain WHY sparse fusion works — then drive the *incremental*
//! fused-mode engine: fuse all three adapters, reweight one, unfuse one,
//! each in O(that adapter's nnz) and bit-identical to a from-scratch
//! rebuild.
//!
//! Run: `cargo run --release --example multi_adapter_fusion [--fast]`

use std::sync::Arc;

use shira::adapter::mask::MaskStrategy;
use shira::config::RunConfig;
use shira::coordinator::fusion;
use shira::coordinator::fusion_engine::{FusionEngine, FusionPlan};
use shira::coordinator::switch::SwitchEngine;
use shira::data::tasks::Task;
use shira::runtime::{HostValue, Runtime};
use shira::train::eval::eval_task;
use shira::train::schedule::Schedule;
use shira::train::{Trainer, TrainKind};
use shira::util::cli::Args;
use shira::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    shira::util::log::init();
    let args = Args::from_env(&[]).map_err(|e| anyhow::anyhow!("{e}"))?;
    let cfg = RunConfig::from_args(&args).map_err(|e| anyhow::anyhow!(e))?;
    let rt = match Runtime::with_default_artifacts() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!(
                "skipping multi_adapter_fusion: artifacts not built (run `make artifacts`): {e}"
            );
            return Ok(());
        }
    };
    let base = shira::repro::ensure_llama_base(&rt, &cfg, "llama_a")?;
    let tasks = [Task::BoolQ, Task::Piqa, Task::ArcEasy];
    let meta = rt.manifest.model("llama").unwrap();
    let (b, t) = (meta.dim("batch"), meta.dim("seq_len"));

    // ---- independent adapters ------------------------------------------
    let mut adapters = Vec::new();
    for (i, &task) in tasks.iter().enumerate() {
        let trainer = Trainer::new(&rt, "llama", base.clone())?;
        let seed = cfg.seed;
        let mut data = move |_s: usize, rng: &mut Rng| {
            let batch = shira::data::tasks::mixture_batch(&[task], b, t, seed, rng);
            vec![
                HostValue::i32(batch.x, vec![b, t]),
                HostValue::i32(batch.y, vec![b, t]),
                HostValue::f32(batch.mask, vec![b, t]),
            ]
        };
        let out = trainer.train(
            TrainKind::Shira(MaskStrategy::WeightMagnitude),
            cfg.adapter_steps,
            Schedule::Linear { lr: cfg.lr_shira as f32, floor_frac: 0.1 },
            &mut data,
            cfg.seed ^ (300 + i as u64),
        )?;
        adapters.push(trainer.export_shira(&out, task.name(), MaskStrategy::WeightMagnitude));
    }

    // ---- interference analysis ------------------------------------------
    let refs: Vec<&shira::adapter::ShiraAdapter> = adapters.iter().collect();
    let report = fusion::analyze_shira(&refs);
    println!("interference across {} independently trained adapters:", refs.len());
    println!("  mean support overlap : {:.4}", report.mean_overlap);
    println!("  mean A1ᵀA2 density   : {:.4}  (LoRA fused products: 1.0)", report.mean_ata_density);
    println!("  colliding entries    : {}", report.collisions);
    println!("  per-pair breakdown (the engine's conflict-free scheduling input):");
    for p in &report.pairs {
        println!(
            "    {} × {} : {} collisions (overlap {:.4})",
            tasks[p.i].name(),
            tasks[p.j].name(),
            p.collisions,
            p.overlap
        );
    }

    // ---- naive fusion + accuracy retention -------------------------------
    let fused = fusion::fuse_shira(&refs, "boolq+piqa+arc_e")?;
    println!(
        "\nfused adapter: {} nnz ({} bytes) — naive sparse addition, no retraining",
        fused.param_count(),
        fused.nbytes()
    );
    println!("\n| task | base | own adapter | fused (3 concepts) | drop vs own |");
    println!("|---|---|---|---|---|");
    let mut single_avg = 0.0;
    let mut multi_avg = 0.0;
    for (task, adapter) in tasks.iter().zip(adapters.iter()) {
        let base_acc = 100.0 * eval_task(&rt, &base, *task, cfg.eval_examples, cfg.seed)?;
        let mut w1 = base.clone();
        SwitchEngine::new().switch_to_shira(&mut w1, adapter, 1.0);
        let own = 100.0 * eval_task(&rt, &w1, *task, cfg.eval_examples, cfg.seed)?;
        let mut w2 = base.clone();
        SwitchEngine::new().switch_to_shira(&mut w2, &fused, 1.0);
        let multi = 100.0 * eval_task(&rt, &w2, *task, cfg.eval_examples, cfg.seed)?;
        println!(
            "| {} | {base_acc:.1}% | {own:.1}% | {multi:.1}% | {:.1} |",
            task.name(),
            own - multi
        );
        single_avg += own / tasks.len() as f64;
        multi_avg += multi / tasks.len() as f64;
    }
    println!(
        "\naverage: single {single_avg:.1}% -> fused {multi_avg:.1}% (%Drop = {:.2})",
        single_avg - multi_avg
    );
    println!("paper shape (Table 4): SHiRA's %Drop stays small because sparse");
    println!("supports barely collide; dense LoRA fusion interferes everywhere.");

    // ---- incremental fused-mode engine ----------------------------------
    // A LoRA-merge deployment would rebuild W for every change below
    // (O(total params)); the FusionPlan makes each step O(the touched
    // adapter's nnz) while staying bit-identical to a serial rebuild.
    println!("\n== incremental fused-mode engine ==");
    let roster: Vec<Arc<shira::adapter::ShiraAdapter>> =
        adapters.iter().cloned().map(Arc::new).collect();
    let plan = FusionPlan::build(roster)?;
    println!(
        "plan over {} adapters: union support {} entries",
        plan.len(),
        plan.union_nnz()
    );
    let mut engine = FusionEngine::new(plan);
    let mut live = base.clone();
    engine.activate(&mut live)?; // one-time base snapshot on the union

    // Fuse all three, one incremental pass each (O(nnz_i) per op).
    for (task, adapter) in tasks.iter().zip(adapters.iter()) {
        engine.fuse_into(&mut live, &adapter.name, 1.0)?;
        println!(
            "  fuse_into({:8}) touched {:6} entries; fused set now {:?}",
            task.name(),
            adapter.param_count(),
            engine.fused_members().iter().map(|(n, _)| *n).collect::<Vec<_>>()
        );
    }
    // The incremental path lands on EXACTLY the serial fuse_shira bytes:
    let mut reference = base.clone();
    SwitchEngine::new().switch_to_shira(&mut reference, &fused, 1.0);
    assert!(live.bit_equal(&reference));
    println!("  state bit-identical to the serial fuse_shira rebuild ✓");

    // Reweight one concept in place — no unfuse/refuse of the other two.
    // (With LoRA-merge, softening one style means rebuilding everything.)
    engine.reweight_one(&mut live, adapters[1].name.as_str(), 0.5)?;
    println!(
        "  reweight_one({}, 0.5) touched {} entries (set total {})",
        adapters[1].name,
        adapters[1].param_count(),
        fused.param_count()
    );
    let acc = 100.0 * eval_task(&rt, &live, tasks[1], cfg.eval_examples, cfg.seed)?;
    println!("    {} accuracy at half strength: {acc:.1}%", tasks[1].name());

    // Unfuse one concept entirely; the remaining two are untouched except
    // at (rare) colliding entries, which are recomputed from the base
    // snapshot — never subtracted from live weights, so no float drift.
    engine.unfuse_one(&mut live, adapters[2].name.as_str())?;
    println!(
        "  unfuse_one({}) touched {} entries; fused set now {:?}",
        adapters[2].name,
        adapters[2].param_count(),
        engine.fused_members().iter().map(|(n, _)| *n).collect::<Vec<_>>()
    );

    // Unfusing the rest restores the base weights bit-exactly — the same
    // exact-revert guarantee single-adapter SHiRA switching has, now in
    // fused mode.  LoRA merge-unmerge leaves float residue instead.
    engine.unfuse_one(&mut live, adapters[0].name.as_str())?;
    engine.unfuse_one(&mut live, adapters[1].name.as_str())?;
    assert!(live.bit_equal(&base));
    println!("  unfused all -> base restored bit-exactly ✓");
    println!(
        "\nconcept-loss stays low (sparse supports barely collide) AND the\n\
         fused set is editable in place — that is what LoRA merging cannot do."
    );
    Ok(())
}
