"""Build-time configuration shared by the L2 model, the AOT pipeline and the
python test-suite.

Everything here is *static at trace time*: the rust runtime learns the
resulting shapes/orders from `artifacts/manifest.json`, never from this file.
"""

from dataclasses import dataclass, field
from typing import List, Tuple


@dataclass(frozen=True)
class LlamaConfig:
    """`nanollama` — the GPT-style stand-in for LLaMA-7B / LLaMA2-7B.

    The paper's claims are relative (SHiRA vs LoRA vs DoRA at matched
    %params); see DESIGN.md §3 for the substitution argument.
    """

    name: str = "llama_a"
    vocab: int = 256
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 3
    d_ff: int = 256  # 2x d_model
    seq_len: int = 32
    batch: int = 8

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def param_spec(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Ordered (name, shape) list — THE canonical parameter order.

        The rust side feeds literals in exactly this order (recorded in the
        manifest); keep it deterministic and append-only.
        """
        spec: List[Tuple[str, Tuple[int, ...]]] = [
            ("embed", (self.vocab, self.d_model)),
            ("pos", (self.seq_len, self.d_model)),
        ]
        for i in range(self.n_layers):
            spec += [
                (f"l{i}.ln1", (self.d_model,)),
                (f"l{i}.wq", (self.d_model, self.d_model)),
                (f"l{i}.wk", (self.d_model, self.d_model)),
                (f"l{i}.wv", (self.d_model, self.d_model)),
                (f"l{i}.wo", (self.d_model, self.d_model)),
                (f"l{i}.ln2", (self.d_model,)),
                (f"l{i}.w_up", (self.d_model, self.d_ff)),
                (f"l{i}.w_down", (self.d_ff, self.d_model)),
            ]
        spec += [
            ("lnf", (self.d_model,)),
            ("head", (self.d_model, self.vocab)),
        ]
        return spec

    def target_names(self) -> List[str]:
        """Adapter target modules — q,k,v,up,down per layer (paper Table 8)."""
        names = []
        for i in range(self.n_layers):
            names += [f"l{i}.wq", f"l{i}.wk", f"l{i}.wv", f"l{i}.w_up", f"l{i}.w_down"]
        return names


@dataclass(frozen=True)
class SdConfig:
    """`nanosd` — MLP generator stand-in for Stable-Diffusion style transfer.

    Maps a content latent z to an "image" feature vector; style adapters
    shift the output distribution while content identity must survive.
    """

    name: str = "sd"
    d_z: int = 16
    d_hidden: int = 96
    n_hidden: int = 3
    d_img: int = 48
    batch: int = 16

    def param_spec(self) -> List[Tuple[str, Tuple[int, ...]]]:
        spec: List[Tuple[str, Tuple[int, ...]]] = [("w_in", (self.d_z, self.d_hidden))]
        for i in range(self.n_hidden - 1):
            spec.append((f"w_h{i}", (self.d_hidden, self.d_hidden)))
        spec.append(("w_out", (self.d_hidden, self.d_img)))
        return spec

    def target_names(self) -> List[str]:
        return [name for name, _ in self.param_spec()]


@dataclass(frozen=True)
class AdapterConfig:
    """Sparsity / rank knobs shared across adapter kinds."""

    # Parameter-matched regime (paper Table 2: SHiRA 1.0% vs LoRA 0.83%):
    # at d_model=128, rank-2 LoRA gives ~1.6% trainable params and a 2.5%
    # SHiRA mask gives ~1.5%.
    shira_frac: float = 0.025  # fraction of each target matrix trainable
    lora_rank: int = 2
    lora_alpha: float = 4.0  # effective scale = lora_alpha / lora_rank


# Default build configs.  Two llama bases (different pretrain seed) stand in
# for LLaMA-7B vs LLaMA2-7B (Tables 2 vs 3).
LLAMA_A = LlamaConfig(name="llama_a")
LLAMA_B = LlamaConfig(name="llama_b")
SD = SdConfig()
ADAPTER = AdapterConfig()

# Serving-side pallas demo artifacts (exercise L1 kernels in real HLO).
APPLY_DIM = 512
APPLY_K = int(APPLY_DIM * APPLY_DIM * 0.02)
