"""L1 Pallas kernel: dense LoRA fuse `W' = W + scale * A @ B`.

This is the *baseline* op the paper compares against (Fig. 5 / Table 5 /
Appendix B): fusing a LoRA adapter rewrites the ENTIRE weight tensor with a
rank-r outer product.  We keep it deliberately well-tiled so the
scatter-vs-fuse gap is not an artifact of a strawman baseline.

TPU mapping: grid over (n/bm, m/bn) output tiles; each program loads the
(bm, r) slice of A and the (r, bn) slice of B (r = LoRA rank, small, so both
fit VMEM trivially), performs one MXU matmul with an f32 accumulator, adds
the W tile, writes back.  No k-grid is needed because r <= 64 always.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fuse_kernel(w_ref, a_ref, b_ref, s_ref, o_ref):
    w = w_ref[...]
    a = a_ref[...]
    b = b_ref[...]
    scale = s_ref[0, 0]
    # f32 accumulation on the MXU (preferred_element_type pins the accumulator).
    o_ref[...] = w + scale * jnp.dot(a, b, preferred_element_type=jnp.float32)


def pick_tiles(n: int, m: int, bm: int = 256, bn: int = 256):
    bm = min(bm, n)
    bn = min(bn, m)
    while n % bm:
        bm -= 1
    while m % bn:
        bn -= 1
    return bm, bn


def lora_fuse(w, a, b, scale, *, bm: int | None = None, bn: int | None = None):
    """`W + scale * A @ B` with (bm, bn) output tiling.

    Args:
      w: (n, m) f32.  a: (n, r) f32.  b: (r, m) f32.  scale: (1, 1) f32.
    """
    n, m = w.shape
    r = a.shape[1]
    tbm, tbn = pick_tiles(n, m)
    bm = bm or tbm
    bn = bn or tbn
    return pl.pallas_call(
        _fuse_kernel,
        out_shape=jax.ShapeDtypeStruct((n, m), w.dtype),
        grid=(n // bm, m // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((r, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(w, a, b, scale)
