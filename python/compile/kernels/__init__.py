"""L1 Pallas kernels (interpret=True) and their pure-jnp oracle."""

from .lora_fuse import lora_fuse, pick_tiles
from .masked_grad import masked_grad
from .scatter_update import (
    partition_updates,
    pick_block_rows,
    scatter_update,
    scatter_update_flat,
)

__all__ = [
    "lora_fuse",
    "pick_tiles",
    "masked_grad",
    "partition_updates",
    "pick_block_rows",
    "scatter_update",
    "scatter_update_flat",
]
