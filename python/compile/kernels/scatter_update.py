"""L1 Pallas kernel: sparse scatter-overwrite of a weight matrix.

This is the paper's `scatter_op` hot path (§3.2, Appendix B): applying a
SHiRA adapter means overwriting the 1-2% of base-weight entries named by the
adapter's flat indices — NOT a dense `W + AB` fuse.

TPU mapping (DESIGN.md §4): the grid walks row-tiles of `W`; each program
moves one `(block_rows, m)` tile HBM→VMEM via BlockSpec, applies the updates
that land in its tile, and writes the tile back.  The host pre-partitions the
(sorted) update stream into per-tile padded segments so the kernel body is a
single vectorized masked scatter — no atomics, no dynamic shapes.  Padding
slots carry the local index `block_rows*m` (one past the tile), which
`mode="drop"` discards.

VMEM per program: block_rows*m*4 B (tile) + kmax*8 B (idx+val) — block_rows
is chosen so this stays well under the ~16 MiB VMEM budget.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU performance is *estimated* in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _masked_overwrite(w, idx, vals, tile_elems, m):
    """Exact overwrite of `w.flat[idx] <- vals`, ignoring entries whose index
    is outside [0, tile_elems).

    Implementation note: `.at[].set(mode="drop")` mis-handles out-of-bounds
    rows under pallas interpret mode, so we use a padding-safe scatter-add
    formulation instead: count real hits per cell and sum real values per
    cell, then select.  REQUIRES unique in-bounds indices (SHiRA masks are
    unique by construction); padded/foreign entries contribute zero.
    """
    oob = (idx < 0) | (idx >= tile_elems)
    safe = jnp.where(oob, 0, idx)
    r = safe // m
    c = safe % m
    hit = jnp.where(oob, 0.0, 1.0).astype(w.dtype)
    cnt = jnp.zeros_like(w).at[r, c].add(hit)
    sval = jnp.zeros_like(w).at[r, c].add(jnp.where(oob, 0.0, vals))
    return jnp.where(cnt > 0, sval, w)


def _scatter_kernel(w_ref, idx_ref, val_ref, o_ref, *, m, block_rows):
    """One grid step: overwrite this row-tile at the tile-local flat indices."""
    w = w_ref[...]
    idx = idx_ref[...].reshape(-1)  # (kmax,) tile-local flat indices, padded OOB
    vals = val_ref[...].reshape(-1)
    o_ref[...] = _masked_overwrite(w, idx, vals, block_rows * m, m)


def pick_block_rows(n: int, m: int, vmem_budget_bytes: int = 4 * 1024 * 1024) -> int:
    """Choose the row-tile height so a tile fits the VMEM budget."""
    rows = max(1, vmem_budget_bytes // (4 * m))
    rows = min(rows, n)
    # Round down to a divisor of n to keep the grid exact.
    while n % rows != 0:
        rows -= 1
    return rows


def partition_updates(idx: np.ndarray, vals: np.ndarray, n: int, m: int,
                      block_rows: int):
    """Host-side prep: split a sorted flat-index update stream into per-tile
    padded segments.

    Returns (tile_idx[g, kmax] int32, tile_val[g, kmax] f32) where g = n //
    block_rows and kmax is the max per-tile population (shared static shape).
    Padding uses local index block_rows*m (OOB => dropped by the kernel).
    """
    assert n % block_rows == 0
    g = n // block_rows
    order = np.argsort(idx, kind="stable")
    idx = np.asarray(idx)[order].astype(np.int64)
    vals = np.asarray(vals)[order].astype(np.float32)
    tile_of = idx // (block_rows * m)
    counts = np.bincount(tile_of, minlength=g)
    kmax = max(1, int(counts.max()) if len(idx) else 1)
    pad_idx = block_rows * m  # one past the tile => drop
    tile_idx = np.full((g, kmax), pad_idx, dtype=np.int32)
    tile_val = np.zeros((g, kmax), dtype=np.float32)
    start = 0
    for t in range(g):
        cnt = int(counts[t])
        seg = slice(start, start + cnt)
        tile_idx[t, :cnt] = (idx[seg] - t * block_rows * m).astype(np.int32)
        tile_val[t, :cnt] = vals[seg]
        start += cnt
    return tile_idx, tile_val


def scatter_update(w, tile_idx, tile_val, *, block_rows: int):
    """`W.at[flat idx] <- vals` over row-tiles.  See `partition_updates`.

    Args:
      w: (n, m) f32 base weight.
      tile_idx: (g, kmax) i32 tile-local flat indices (padded OOB).
      tile_val: (g, kmax) f32 values.
    Returns (n, m) updated weight.
    """
    n, m = w.shape
    g, kmax = tile_idx.shape
    assert g * block_rows == n, (g, block_rows, n)
    kernel = functools.partial(_scatter_kernel, m=m, block_rows=block_rows)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, m), w.dtype),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((block_rows, m), lambda i: (i, 0)),
            pl.BlockSpec((1, kmax), lambda i: (i, 0)),
            pl.BlockSpec((1, kmax), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, m), lambda i: (i, 0)),
        interpret=True,
    )(w, tile_idx, tile_val)


def scatter_update_flat(w, idx, vals, *, block_rows: int | None = None):
    """Convenience wrapper for *traced* use with host-static indices.

    When indices are only known at runtime (the usual case for the rust
    serving path), prefer `partition_updates` + `scatter_update` so the
    per-tile segmentation happens on the host.  This wrapper accepts runtime
    `idx` by scattering per-tile with a dense mask — used by the
    `apply_shira` artifact where k is static but the index *values* are
    runtime inputs: every tile receives the full update list and drops
    entries that fall outside it.
    """
    n, m = w.shape
    if block_rows is None:
        block_rows = pick_block_rows(n, m)
    g = n // block_rows

    def kernel(w_ref, idx_ref, val_ref, o_ref):
        t = pl.program_id(0)
        w_tile = w_ref[...]
        idx_all = idx_ref[...].reshape(-1)
        vals_all = val_ref[...].reshape(-1)
        # Entries outside this tile become OOB (negative or >= tile size)
        # and are ignored by the padding-safe overwrite.
        local = idx_all - t * block_rows * m
        o_ref[...] = _masked_overwrite(w_tile, local, vals_all,
                                       block_rows * m, m)

    k = idx.shape[0]
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, m), w.dtype),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((block_rows, m), lambda i: (i, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, m), lambda i: (i, 0)),
        interpret=True,
    )(w, idx, vals)
