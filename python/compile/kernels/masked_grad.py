"""L1 Pallas kernel: Hadamard gradient masking `g' = g * M` (paper Fig. 2b).

The dense-mask formulation of SHiRA training: after backprop, gradients are
multiplied elementwise by a {0,1} mask so only the sparse trainable subset
moves.  (The memory-efficient train step in model.py avoids the dense mask
entirely by differentiating w.r.t. the gathered value vector — this kernel
implements the paper's *gradient-hook* formulation, Appendix C, and is used
by the `masked_grad` artifact + ablation benches.)

TPU mapping: pure VPU elementwise over (block_rows, m) tiles.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .scatter_update import pick_block_rows


def _mask_kernel(g_ref, m_ref, o_ref):
    o_ref[...] = g_ref[...] * m_ref[...]


def masked_grad(g, mask, *, block_rows: int | None = None):
    """Elementwise `g * mask` over row tiles; shapes (n, m)."""
    n, m = g.shape
    if block_rows is None:
        block_rows = pick_block_rows(n, m)
    return pl.pallas_call(
        _mask_kernel,
        out_shape=jax.ShapeDtypeStruct((n, m), g.dtype),
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, m), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, m), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, m), lambda i: (i, 0)),
        interpret=True,
    )(g, mask)
