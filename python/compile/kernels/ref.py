"""Pure-jnp oracles for every L1 kernel — THE correctness reference.

pytest (python/tests/test_kernels.py) asserts kernel == oracle across a
hypothesis-driven sweep of shapes, sparsity levels and dtypes.
"""

import jax.numpy as jnp


def scatter_update_ref(w, idx, vals):
    """`W.flat[idx] <- vals` (duplicate indices: last write wins after a
    stable sort by index, matching the kernel's sorted update stream)."""
    n, m = w.shape
    flat = w.reshape(-1)
    flat = flat.at[idx].set(vals)
    return flat.reshape(n, m)


def lora_fuse_ref(w, a, b, scale):
    return w + scale * (a @ b)


def masked_grad_ref(g, mask):
    return g * mask


def gather_ref(w, idx):
    """Extract adapter values: vals = W.flat[idx]."""
    return w.reshape(-1)[idx]
