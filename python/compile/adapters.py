"""L2 adapter machinery: effective-weight builders + the generic train step.

Every adapter kind reduces to the same interface:

    theta  f32[K]   — the flat trainable vector (layout in params.py)
    idx    i32[K']  — flat LOCAL indices (sparse kinds only; K' = sparse part)
    build_effective(base, theta, idx) -> params dict with adapter applied

and the train step is one generic Adam step over `theta`:

    (base..., theta, m, v, idx, step, lr, batch...) ->
        (theta', m', v', loss)

This is the paper's *memory-efficient PEFT formulation* (Appendix D): for
SHiRA the trainable leaf is the gathered value vector, so optimizer state is
O(K)=O(0.01·nm), never O(nm) — the structural source of Table 6's ~16 % peak
memory saving.  The dense-mask formulation (Appendix C, gradient hooks) is
also provided (`shira_dense`) and routes its gradient Hadamard through the
L1 Pallas `masked_grad` kernel.
"""

from typing import Dict, List

import jax
import jax.numpy as jnp

from . import params as P
from .kernels import masked_grad


ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


# ---------------------------------------------------------------------------
# Effective-weight builders
# ---------------------------------------------------------------------------

def _scatter_into(w, local_idx, vals):
    """W.flat[idx] <- vals (sparse overwrite; the SHiRA fuse)."""
    n, m = w.shape
    return w.reshape(-1).at[local_idx].set(vals).reshape(n, m)


def effective_shira(base: Dict, theta, idx, layout: List[dict]) -> Dict:
    """SHiRA: overwrite each target's sparse entries with theta segments."""
    out = dict(base)
    for ent in layout:
        seg = slice(ent["off"], ent["off"] + ent["k"])
        out[ent["name"]] = _scatter_into(base[ent["name"]], idx[seg], theta[seg])
    return out


def effective_lora(base: Dict, theta, layout: List[dict], scale: float) -> Dict:
    """LoRA (fused form): W + scale * A @ B for each target."""
    out = dict(base)
    for ent in layout:
        n, m, r = ent["shape"][0], ent["shape"][1], ent["r"]
        a = theta[ent["a_off"]:ent["a_off"] + ent["a_len"]].reshape(n, r)
        b = theta[ent["b_off"]:ent["b_off"] + ent["b_len"]].reshape(r, m)
        out[ent["name"]] = base[ent["name"]] + scale * (a @ b)
    return out


def lora_branches(theta, layout: List[dict]):
    """(A, B) per target — for the UNFUSED serving mode (Appendix A)."""
    branches = {}
    for ent in layout:
        n, m, r = ent["shape"][0], ent["shape"][1], ent["r"]
        a = theta[ent["a_off"]:ent["a_off"] + ent["a_len"]].reshape(n, r)
        b = theta[ent["b_off"]:ent["b_off"] + ent["b_len"]].reshape(r, m)
        branches[ent["name"]] = (a, b)
    return branches


def _column_normalize(w_dir, mag, eps=1e-6):
    norm = jnp.sqrt(jnp.sum(w_dir * w_dir, axis=0, keepdims=True) + eps)
    return mag[None, :] * w_dir / norm


def effective_dora(base: Dict, theta, layout: List[dict], scale: float) -> Dict:
    """DoRA: W' = mag ⊙_col (W + scale·AB) / ||W + scale·AB||_col."""
    out = dict(base)
    for ent in layout:
        n, m, r = ent["shape"][0], ent["shape"][1], ent["r"]
        a = theta[ent["a_off"]:ent["a_off"] + ent["a_len"]].reshape(n, r)
        b = theta[ent["b_off"]:ent["b_off"] + ent["b_len"]].reshape(r, m)
        mag = theta[ent["mag_off"]:ent["mag_off"] + ent["mag_len"]]
        w_dir = base[ent["name"]] + scale * (a @ b)
        out[ent["name"]] = _column_normalize(w_dir, mag)
    return out


def effective_shira_dora(base: Dict, theta, idx, layout: List[dict]) -> Dict:
    """SHiRA-WM-DoRA (paper §4.3.1): sparse high-rank direction + magnitudes.

    The direction matrix is the base weight with 1 % entries overwritten by
    trainable values; per-column magnitudes are also trainable.  Fused form
    still only changes ~1 % of entries plus column scales.
    """
    out = dict(base)
    for ent in layout:
        seg = slice(ent["off"], ent["off"] + ent["k"])
        mag = theta[ent["mag_off"]:ent["mag_off"] + ent["mag_len"]]
        w_dir = _scatter_into(base[ent["name"]], idx[seg], theta[seg])
        out[ent["name"]] = _column_normalize(w_dir, mag)
    return out


def effective_full(theta, cfg) -> Dict:
    """Full finetuning: theta IS the whole parameter set (pretraining)."""
    out = {}
    for ent in P.full_layout(cfg):
        seg = theta[ent["off"]:ent["off"] + ent["len"]]
        out[ent["name"]] = seg.reshape(ent["shape"])
    return out


def effective_shira_dense(base: Dict, theta, layout: List[dict]) -> Dict:
    """Appendix-C formulation: theta holds FULL dense target matrices.

    Gradient sparsification happens in the custom VJP below via the Pallas
    `masked_grad` kernel; this builder just splices the dense targets in.
    """
    out = dict(base)
    for ent in layout:
        seg = theta[ent["off"]:ent["off"] + ent["len"]]
        out[ent["name"]] = seg.reshape(ent["shape"])
    return out


# ---------------------------------------------------------------------------
# Adam (bias-corrected) over the flat theta vector
# ---------------------------------------------------------------------------

def adam_update(theta, g, m, v, step_i32, lr):
    step = step_i32.astype(jnp.float32) + 1.0
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mhat = m / (1.0 - jnp.power(ADAM_B1, step))
    vhat = v / (1.0 - jnp.power(ADAM_B2, step))
    theta = theta - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return theta, m, v


# ---------------------------------------------------------------------------
# Train-step factories (one per adapter kind x model family)
# ---------------------------------------------------------------------------

def make_train_step(family: str, kind: str, cfg, acfg):
    """Return f(base_flat.., theta, m, v, [idx,] step, lr, batch..) -> tuple.

    `family` is "llama" (batch = tokens,targets,mask) or "sd" (batch =
    z,target).  `kind` is one of full|shira|lora|dora|shira_dora|shira_dense.
    Loss is computed on the adapter-effective parameters; autodiff is taken
    w.r.t. theta only — base weights are frozen inputs.
    """
    from . import model as M

    spec = cfg.param_spec()
    n_base = len(spec)
    scale = acfg.lora_alpha / acfg.lora_rank
    layouts = {
        "shira": P.shira_layout(cfg, acfg),
        "lora": P.lora_layout(cfg, acfg),
        "dora": P.dora_layout(cfg, acfg),
        "shira_dora": P.shira_dora_layout(cfg, acfg),
        "shira_dense": P.probe_layout(cfg),
    }

    def loss_fn(base, eff_params, batch):
        if family == "llama":
            tokens, targets, mask = batch
            return M.llama_loss(eff_params, tokens, targets, mask, cfg)
        z, target = batch
        return M.sd_loss(eff_params, z, target, cfg)

    has_idx = kind in ("shira", "shira_dora")

    def step_fn(*args):
        base_flat = list(args[:n_base]) if kind != "full" else None
        rest = args[n_base:] if kind != "full" else args
        if has_idx:
            theta, m, v, idx, step, lr = rest[:6]
            batch = rest[6:]
        else:
            theta, m, v, step, lr = rest[:5]
            batch = rest[5:]
            idx = None
        base = P.unflatten_params(base_flat, cfg) if base_flat is not None else None
        # shira_dense carries the dense {0,1} gradient mask as the final
        # input, after the data batch.
        data_batch = batch[:-1] if kind == "shira_dense" else batch

        def objective(th):
            if kind == "full":
                eff = effective_full(th, cfg)
            elif kind == "shira":
                eff = effective_shira(base, th, idx, layouts["shira"])
            elif kind == "lora":
                eff = effective_lora(base, th, layouts["lora"], scale)
            elif kind == "dora":
                eff = effective_dora(base, th, layouts["dora"], scale)
            elif kind == "shira_dora":
                eff = effective_shira_dora(base, th, idx, layouts["shira_dora"])
            elif kind == "shira_dense":
                eff = effective_shira_dense(base, th, layouts["shira_dense"])
            else:
                raise ValueError(kind)
            return loss_fn(base, eff, data_batch)

        loss, g = jax.value_and_grad(objective)(theta)
        if kind == "shira_dense":
            # Appendix C: Hadamard gradient masking through the L1 Pallas
            # kernel, one row-tiled launch per target matrix.  The mask is
            # the dense {0,1} complement of the sparse index set, provided
            # as an extra input after the batch.
            mask_flat = batch[-1]
            masked = []
            for ent in layouts["shira_dense"]:
                seg = slice(ent["off"], ent["off"] + ent["len"])
                gm = masked_grad(
                    g[seg].reshape(ent["shape"]),
                    mask_flat[seg].reshape(ent["shape"]),
                )
                masked.append(gm.reshape(-1))
            g = jnp.concatenate(masked)
        theta2, m2, v2 = adam_update(theta, g, m, v, step, lr)
        return theta2, m2, v2, loss

    return step_fn


def make_grad_probe(family: str, cfg):
    """f(base_flat.., batch..) -> (|grad| over targets concat, loss).

    Used by the rust mask calibrator for SHiRA-Grad / SHiRA-SNIP: run a few
    calibration batches, accumulate |g|, take the per-layer top-k.
    """
    from . import model as M

    spec = cfg.param_spec()
    n_base = len(spec)
    probe = P.probe_layout(cfg)

    def probe_fn(*args):
        base_flat = list(args[:n_base])
        batch = args[n_base:]
        base = P.unflatten_params(base_flat, cfg)

        def objective(targets_flat):
            eff = dict(base)
            for ent in probe:
                seg = targets_flat[ent["off"]:ent["off"] + ent["len"]]
                eff[ent["name"]] = seg.reshape(ent["shape"])
            if family == "llama":
                tokens, targets, mask = batch
                return M.llama_loss(eff, tokens, targets, mask, cfg)
            z, target = batch
            return M.sd_loss(eff, z, target, cfg)

        t0 = jnp.concatenate([base[e["name"]].reshape(-1) for e in probe])
        loss, g = jax.value_and_grad(objective)(t0)
        return jnp.abs(g), loss

    return probe_fn
