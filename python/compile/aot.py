"""AOT pipeline: lower every L2 graph to HLO *text* + write the manifest.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the rust `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Run via `make artifacts` (a no-op when inputs are unchanged):

    cd python && python -m compile.aot --out ../artifacts

Python runs ONCE at build time; the rust binary is self-contained after
artifacts exist and python is never on the request path.
"""

import argparse
import hashlib
import json
import os
from typing import List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import adapters as A
from . import configs as C
from . import model as M
from . import params as P
from .kernels import lora_fuse, masked_grad, scatter_update_flat


F32, I32 = jnp.float32, jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def base_specs(cfg) -> List[jax.ShapeDtypeStruct]:
    return [spec(s) for _, s in cfg.param_spec()]


def named_base(cfg):
    return [
        {"name": n, "dtype": "f32", "shape": list(s)} for n, s in cfg.param_spec()
    ]


def io_entry(name, dtype, shape):
    return {"name": name, "dtype": dtype, "shape": [int(x) for x in shape]}


class Builder:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest_artifacts = {}
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name: str, fn, in_specs, input_meta, output_meta):
        # keep_unused=True: the manifest declares EVERY input, so the
        # compiled program must too (shira_dense never reads the base
        # target weights and jit would otherwise prune those parameters).
        lowered = jax.jit(fn, keep_unused=True).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(self.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        self.manifest_artifacts[name] = {
            "file": fname,
            "inputs": input_meta,
            "outputs": output_meta,
            "hlo_bytes": len(text),
        }
        print(f"  emitted {name:28s} {len(text)/1024:9.1f} KiB")


def build_llama(b: Builder, cfg, acfg):
    B, T, V = cfg.batch, cfg.seq_len, cfg.vocab
    base_meta = named_base(cfg)
    batch_meta = [
        io_entry("tokens", "i32", [B, T]),
        io_entry("targets", "i32", [B, T]),
        io_entry("loss_mask", "f32", [B, T]),
    ]
    batch_specs = [spec([B, T], I32), spec([B, T], I32), spec([B, T], F32)]

    # --- forward (fused-mode inference; adapters already applied to weights)
    def fwd(*args):
        base = P.unflatten_params(list(args[:-1]), cfg)
        return (M.llama_fwd(base, args[-1], cfg),)

    b.emit(
        "llama_fwd", fwd, base_specs(cfg) + [spec([B, T], I32)],
        base_meta + [io_entry("tokens", "i32", [B, T])],
        [io_entry("logits", "f32", [B, T, V])],
    )

    # --- unfused LoRA forward (Appendix A option ii: branches on hot path)
    k_lora = P.lora_theta_len(cfg, acfg)
    lora_layout = P.lora_layout(cfg, acfg)
    scale = acfg.lora_alpha / acfg.lora_rank

    def fwd_unfused(*args):
        base = P.unflatten_params(list(args[:-2]), cfg)
        theta, tokens = args[-2], args[-1]
        branches = A.lora_branches(theta, lora_layout)
        return (M.llama_fwd(base, tokens, cfg, lora_branch=branches,
                            lora_scale=scale),)

    b.emit(
        "llama_fwd_unfused_lora", fwd_unfused,
        base_specs(cfg) + [spec([k_lora]), spec([B, T], I32)],
        base_meta + [io_entry("theta", "f32", [k_lora]),
                     io_entry("tokens", "i32", [B, T])],
        [io_entry("logits", "f32", [B, T, V])],
    )

    # --- train steps
    def train_io(K, with_idx, extra=None):
        ins = [io_entry("theta", "f32", [K]), io_entry("m", "f32", [K]),
               io_entry("v", "f32", [K])]
        specs = [spec([K]), spec([K]), spec([K])]
        if with_idx:
            ins.append(io_entry("idx", "i32", [K_sparse]))
            specs.append(spec([K_sparse], I32))
        ins += [io_entry("step", "i32", []), io_entry("lr", "f32", [])]
        specs += [spec([], I32), spec([], F32)]
        ins += batch_meta
        specs += batch_specs
        if extra:
            for e_meta, e_spec in extra:
                ins.append(e_meta)
                specs.append(e_spec)
        outs = [io_entry("theta_out", "f32", [K]), io_entry("m_out", "f32", [K]),
                io_entry("v_out", "f32", [K]), io_entry("loss", "f32", [])]
        return ins, specs, outs

    K_sparse = P.shira_theta_len(cfg, acfg)
    kinds = {
        "shira": (P.shira_theta_len(cfg, acfg), True, None),
        "lora": (P.lora_theta_len(cfg, acfg), False, None),
        "dora": (P.dora_theta_len(cfg, acfg), False, None),
        "shira_dora": (P.shira_dora_theta_len(cfg, acfg), True, None),
        "full": (P.full_theta_len(cfg), False, None),
        "shira_dense": (
            sum(e["len"] for e in P.probe_layout(cfg)), False,
            [(io_entry("dense_mask", "f32",
                       [sum(e["len"] for e in P.probe_layout(cfg))]),
              spec([sum(e["len"] for e in P.probe_layout(cfg))]))],
        ),
    }
    for kind, (K, with_idx, extra) in kinds.items():
        step_fn = A.make_train_step("llama", kind, cfg, acfg)
        ins, specs_, outs = train_io(K, with_idx, extra)
        if kind == "full":
            full_ins, full_specs = ins, specs_
        else:
            full_ins = base_meta + ins
            full_specs = base_specs(cfg) + specs_
        b.emit(f"llama_train_{kind}", step_fn, full_specs, full_ins, outs)

    # --- grad probe for mask calibration (Grad / SNIP)
    K_probe = sum(e["len"] for e in P.probe_layout(cfg))
    probe_fn = A.make_grad_probe("llama", cfg)
    b.emit(
        "llama_grad_probe", probe_fn, base_specs(cfg) + batch_specs,
        base_meta + batch_meta,
        [io_entry("grad_abs", "f32", [K_probe]), io_entry("loss", "f32", [])],
    )


def build_sd(b: Builder, cfg, acfg):
    B, dz, dimg = cfg.batch, cfg.d_z, cfg.d_img
    base_meta = named_base(cfg)
    batch_meta = [io_entry("z", "f32", [B, dz]),
                  io_entry("target", "f32", [B, dimg])]
    batch_specs = [spec([B, dz]), spec([B, dimg])]

    def fwd(*args):
        base = P.unflatten_params(list(args[:-1]), cfg)
        return (M.sd_fwd(base, args[-1], cfg),)

    b.emit(
        "sd_fwd", fwd, base_specs(cfg) + [spec([B, dz])],
        base_meta + [io_entry("z", "f32", [B, dz])],
        [io_entry("img", "f32", [B, dimg])],
    )

    kinds = {
        "shira": (P.shira_theta_len(cfg, acfg), True),
        "lora": (P.lora_theta_len(cfg, acfg), False),
        "full": (P.full_theta_len(cfg), False),
    }
    K_sparse = P.shira_theta_len(cfg, acfg)
    for kind, (K, with_idx) in kinds.items():
        step_fn = A.make_train_step("sd", kind, cfg, acfg)
        ins = [io_entry("theta", "f32", [K]), io_entry("m", "f32", [K]),
               io_entry("v", "f32", [K])]
        specs_ = [spec([K]), spec([K]), spec([K])]
        if with_idx:
            ins.append(io_entry("idx", "i32", [K_sparse]))
            specs_.append(spec([K_sparse], I32))
        ins += [io_entry("step", "i32", []), io_entry("lr", "f32", [])]
        specs_ += [spec([], I32), spec([], F32)]
        ins += batch_meta
        specs_ += batch_specs
        outs = [io_entry("theta_out", "f32", [K]), io_entry("m_out", "f32", [K]),
                io_entry("v_out", "f32", [K]), io_entry("loss", "f32", [])]
        if kind == "full":
            b.emit(f"sd_train_{kind}", step_fn, specs_, ins, outs)
        else:
            b.emit(f"sd_train_{kind}", step_fn,
                   base_specs(cfg) + specs_, named_base(cfg) + ins, outs)

    K_probe = sum(e["len"] for e in P.probe_layout(cfg))
    probe_fn = A.make_grad_probe("sd", cfg)
    b.emit(
        "sd_grad_probe", probe_fn, base_specs(cfg) + batch_specs,
        named_base(cfg) + batch_meta,
        [io_entry("grad_abs", "f32", [K_probe]), io_entry("loss", "f32", [])],
    )


def build_pallas_demos(b: Builder, acfg):
    """Serving-side artifacts that route through the L1 Pallas kernels."""
    D, K = C.APPLY_DIM, C.APPLY_K
    r = acfg.lora_rank

    def apply_shira(w, idx, vals):
        return (scatter_update_flat(w, idx, vals),)

    b.emit(
        "apply_shira", apply_shira,
        [spec([D, D]), spec([K], I32), spec([K])],
        [io_entry("w", "f32", [D, D]), io_entry("idx", "i32", [K]),
         io_entry("vals", "f32", [K])],
        [io_entry("w_out", "f32", [D, D])],
    )

    def fuse(w, a, bb, s):
        return (lora_fuse(w, a, bb, s),)

    b.emit(
        "fuse_lora", fuse,
        [spec([D, D]), spec([D, r]), spec([r, D]), spec([1, 1])],
        [io_entry("w", "f32", [D, D]), io_entry("a", "f32", [D, r]),
         io_entry("b", "f32", [r, D]), io_entry("scale", "f32", [1, 1])],
        [io_entry("w_out", "f32", [D, D])],
    )

    def mg(g, mask):
        return (masked_grad(g, mask),)

    b.emit(
        "masked_grad_op", mg, [spec([D, D]), spec([D, D])],
        [io_entry("g", "f32", [D, D]), io_entry("mask", "f32", [D, D])],
        [io_entry("g_out", "f32", [D, D])],
    )


def build_manifest(b: Builder, acfg):
    llama, sd = C.LLAMA_A, C.SD
    manifest = {
        "version": 1,
        "artifacts": b.manifest_artifacts,
        "adapter": {
            "shira_frac": acfg.shira_frac,
            "lora_rank": acfg.lora_rank,
            "lora_alpha": acfg.lora_alpha,
            "lora_scale": acfg.lora_alpha / acfg.lora_rank,
            "adam": {"b1": A.ADAM_B1, "b2": A.ADAM_B2, "eps": A.ADAM_EPS},
        },
        "models": {
            "llama": {
                "vocab": llama.vocab, "d_model": llama.d_model,
                "n_heads": llama.n_heads, "n_layers": llama.n_layers,
                "d_ff": llama.d_ff, "seq_len": llama.seq_len,
                "batch": llama.batch,
                "params": [{"name": n, "shape": list(s)}
                           for n, s in llama.param_spec()],
                "targets": llama.target_names(),
                "layout": {
                    "shira": P.shira_layout(llama, acfg),
                    "lora": P.lora_layout(llama, acfg),
                    "dora": P.dora_layout(llama, acfg),
                    "shira_dora": P.shira_dora_layout(llama, acfg),
                    "probe": P.probe_layout(llama),
                    "full": P.full_layout(llama),
                },
                "theta_len": {
                    "shira": P.shira_theta_len(llama, acfg),
                    "lora": P.lora_theta_len(llama, acfg),
                    "dora": P.dora_theta_len(llama, acfg),
                    "shira_dora": P.shira_dora_theta_len(llama, acfg),
                    "full": P.full_theta_len(llama),
                    "shira_dense": sum(e["len"] for e in P.probe_layout(llama)),
                },
            },
            "sd": {
                "d_z": sd.d_z, "d_hidden": sd.d_hidden,
                "n_hidden": sd.n_hidden, "d_img": sd.d_img, "batch": sd.batch,
                "params": [{"name": n, "shape": list(s)}
                           for n, s in sd.param_spec()],
                "targets": sd.target_names(),
                "layout": {
                    "shira": P.shira_layout(sd, acfg),
                    "lora": P.lora_layout(sd, acfg),
                    "probe": P.probe_layout(sd),
                    "full": P.full_layout(sd),
                },
                "theta_len": {
                    "shira": P.shira_theta_len(sd, acfg),
                    "lora": P.lora_theta_len(sd, acfg),
                    "full": P.full_theta_len(sd),
                },
            },
        },
        "pallas_demo": {"dim": C.APPLY_DIM, "k": C.APPLY_K,
                        "rank": acfg.lora_rank},
    }
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    acfg = C.ADAPTER
    b = Builder(args.out)
    print("AOT: lowering L2 graphs to HLO text")
    build_llama(b, C.LLAMA_A, acfg)
    build_sd(b, C.SD, acfg)
    build_pallas_demos(b, acfg)
    manifest = build_manifest(b, acfg)
    path = os.path.join(args.out, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"  wrote manifest.json ({os.path.getsize(path)} bytes), "
          f"{len(b.manifest_artifacts)} artifacts")


if __name__ == "__main__":
    main()
