"""Parameter initialization, canonical flattening, and adapter geometry.

The rust runtime never sees python pytrees: every artifact takes parameters
as a flat, ordered list of arrays (order = `cfg.param_spec()`), and every
adapter's trainable state is a SINGLE flat f32 vector `theta` whose internal
layout (per-target segments, static offsets) is recorded in the manifest.
"""

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def init_params(cfg, seed: int) -> Dict[str, jnp.ndarray]:
    """Deterministic scaled-gaussian init for any param_spec model."""
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in cfg.param_spec():
        if len(shape) == 1:
            params[name] = jnp.ones(shape, jnp.float32)  # norm gains
        else:
            fan_in = shape[0]
            std = 1.0 / math.sqrt(fan_in)
            params[name] = jnp.asarray(
                rng.normal(0.0, std, size=shape), jnp.float32
            )
    return params


def flatten_params(params: Dict[str, jnp.ndarray], cfg) -> List[jnp.ndarray]:
    return [params[name] for name, _ in cfg.param_spec()]


def unflatten_params(flat: List[jnp.ndarray], cfg) -> Dict[str, jnp.ndarray]:
    return {name: arr for (name, _), arr in zip(cfg.param_spec(), flat)}


# ---------------------------------------------------------------------------
# Adapter geometry: how theta's flat layout maps onto target matrices
# ---------------------------------------------------------------------------

def shira_k(shape: Tuple[int, int], frac: float) -> int:
    """Trainable entries for one target = ceil(frac * numel), >= 1."""
    return max(1, int(round(frac * shape[0] * shape[1])))


def shira_layout(cfg, acfg) -> List[dict]:
    """Per-target segments of the SHiRA theta/idx vectors.

    Each entry: {name, shape, k, off} — theta[off:off+k] are the trainable
    values for target `name`, idx[off:off+k] their LOCAL flat indices.
    """
    shapes = dict(cfg.param_spec())
    layout, off = [], 0
    for name in cfg.target_names():
        n, m = shapes[name]
        k = shira_k((n, m), acfg.shira_frac)
        layout.append({"name": name, "shape": [n, m], "k": k, "off": off})
        off += k
    return layout


def lora_layout(cfg, acfg) -> List[dict]:
    """Per-target segments of the LoRA theta vector: [A (n*r) | B (r*m)]."""
    shapes = dict(cfg.param_spec())
    r = acfg.lora_rank
    layout, off = [], 0
    for name in cfg.target_names():
        n, m = shapes[name]
        layout.append(
            {"name": name, "shape": [n, m], "r": r,
             "a_off": off, "a_len": n * r,
             "b_off": off + n * r, "b_len": r * m}
        )
        off += n * r + r * m
    return layout


def dora_layout(cfg, acfg) -> List[dict]:
    """LoRA layout + a per-output-column magnitude vector per target."""
    layout = lora_layout(cfg, acfg)
    off = lora_theta_len(cfg, acfg)
    out = []
    for ent in layout:
        ent = dict(ent)
        m = ent["shape"][1]
        ent["mag_off"] = off
        ent["mag_len"] = m
        off += m
        out.append(ent)
    return out


def shira_dora_layout(cfg, acfg) -> List[dict]:
    """SHiRA-WM-DoRA: sparse direction values + per-column magnitudes."""
    layout = shira_layout(cfg, acfg)
    off = shira_theta_len(cfg, acfg)
    out = []
    for ent in layout:
        ent = dict(ent)
        m = ent["shape"][1]
        ent["mag_off"] = off
        ent["mag_len"] = m
        off += m
        out.append(ent)
    return out


def shira_theta_len(cfg, acfg) -> int:
    return sum(e["k"] for e in shira_layout(cfg, acfg))


def lora_theta_len(cfg, acfg) -> int:
    return sum(e["a_len"] + e["b_len"] for e in lora_layout(cfg, acfg))


def dora_theta_len(cfg, acfg) -> int:
    return lora_theta_len(cfg, acfg) + sum(
        dict(cfg.param_spec())[n][1] for n in cfg.target_names()
    )


def shira_dora_theta_len(cfg, acfg) -> int:
    return shira_theta_len(cfg, acfg) + sum(
        dict(cfg.param_spec())[n][1] for n in cfg.target_names()
    )


def full_theta_len(cfg) -> int:
    return sum(int(np.prod(s)) for _, s in cfg.param_spec())


def full_layout(cfg) -> List[dict]:
    layout, off = [], 0
    for name, shape in cfg.param_spec():
        ln = int(np.prod(shape))
        layout.append({"name": name, "shape": list(shape), "off": off, "len": ln})
        off += ln
    return layout


def probe_layout(cfg) -> List[dict]:
    """Layout of the grad-probe output vector (dense grads over targets)."""
    shapes = dict(cfg.param_spec())
    layout, off = [], 0
    for name in cfg.target_names():
        n, m = shapes[name]
        layout.append({"name": name, "shape": [n, m], "off": off, "len": n * m})
        off += n * m
    return layout
