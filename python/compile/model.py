"""L2: the JAX compute graphs — `nanollama` (LLaMA stand-in) and `nanosd`
(Stable-Diffusion stand-in) forward passes and losses.

Both models are written against a plain name->array dict; adapter-effective
weights are produced by `adapters.py` (scatter / low-rank fuse / DoRA
decomposition) BEFORE the forward, so the forward itself is adapter-agnostic
— exactly the fused-inference dataflow of the paper.  The one exception is
`llama_fwd` with `lora_branch`, which models the paper's UNFUSED LoRA mode
(extra `(x@A)@B` branches on the request path, Appendix A option ii).
"""

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, gain: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    scale = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return x * scale * gain


def _dense(x, params, name, lora_branch, scale):
    """x @ W, plus the unfused LoRA branch when serving in unfused mode."""
    y = x @ params[name]
    if lora_branch is not None and name in lora_branch:
        a, b = lora_branch[name]
        y = y + scale * ((x @ a) @ b)
    return y


# ---------------------------------------------------------------------------
# nanollama
# ---------------------------------------------------------------------------

def llama_fwd(
    params: Dict[str, jnp.ndarray],
    tokens: jnp.ndarray,
    cfg,
    lora_branch: Optional[Dict[str, Tuple[jnp.ndarray, jnp.ndarray]]] = None,
    lora_scale: float = 1.0,
) -> jnp.ndarray:
    """Causal transformer forward.  tokens: i32[B,T] -> logits f32[B,T,V]."""
    B, T = tokens.shape
    h = params["embed"][tokens] + params["pos"][None, :T, :]
    causal = jnp.tril(jnp.ones((T, T), jnp.float32))
    neg = jnp.float32(-1e9)
    for i in range(cfg.n_layers):
        pre = rmsnorm(h, params[f"l{i}.ln1"])
        q = _dense(pre, params, f"l{i}.wq", lora_branch, lora_scale)
        k = _dense(pre, params, f"l{i}.wk", lora_branch, lora_scale)
        v = _dense(pre, params, f"l{i}.wv", lora_branch, lora_scale)
        hd = cfg.head_dim
        q = q.reshape(B, T, cfg.n_heads, hd).transpose(0, 2, 1, 3)
        k = k.reshape(B, T, cfg.n_heads, hd).transpose(0, 2, 1, 3)
        v = v.reshape(B, T, cfg.n_heads, hd).transpose(0, 2, 1, 3)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(hd))
        att = jnp.where(causal[None, None] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(B, T, cfg.d_model)
        h = h + ctx @ params[f"l{i}.wo"]
        pre2 = rmsnorm(h, params[f"l{i}.ln2"])
        up = _dense(pre2, params, f"l{i}.w_up", lora_branch, lora_scale)
        h = h + _dense(jax.nn.silu(up), params, f"l{i}.w_down", lora_branch, lora_scale)
    h = rmsnorm(h, params["lnf"])
    return h @ params["head"]


def llama_loss(params, tokens, targets, mask, cfg, **fwd_kw) -> jnp.ndarray:
    """Masked token-level cross-entropy (mask selects answer positions)."""
    logits = llama_fwd(params, tokens, cfg, **fwd_kw)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt_logit = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - tgt_logit
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# nanosd
# ---------------------------------------------------------------------------

def sd_fwd(params: Dict[str, jnp.ndarray], z: jnp.ndarray, cfg) -> jnp.ndarray:
    """MLP generator: content latent z f32[B,d_z] -> image f32[B,d_img]."""
    h = jax.nn.gelu(z @ params["w_in"])
    for i in range(cfg.n_hidden - 1):
        h = jax.nn.gelu(h @ params[f"w_h{i}"]) + h  # residual hidden blocks
    return h @ params["w_out"]


def sd_loss(params, z, target, cfg) -> jnp.ndarray:
    """Style-transfer finetuning objective: MSE to the styled target image."""
    img = sd_fwd(params, z, cfg)
    return jnp.mean((img - target) ** 2)
