"""AOT pipeline: artifacts exist, parse as HLO text, manifest is coherent.

These tests run against the checked-out `artifacts/` directory when present
(built by `make artifacts`), otherwise they build into a tmpdir once per
session.
"""

import json
import os
import subprocess
import sys

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="session")
def artifacts_dir(tmp_path_factory):
    if os.path.exists(os.path.join(ART, "manifest.json")):
        return os.path.abspath(ART)
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out)],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        check=True,
    )
    return str(out)


@pytest.fixture(scope="session")
def manifest(artifacts_dir):
    with open(os.path.join(artifacts_dir, "manifest.json")) as f:
        return json.load(f)


EXPECTED = [
    "llama_fwd", "llama_fwd_unfused_lora", "llama_train_shira",
    "llama_train_lora", "llama_train_dora", "llama_train_shira_dora",
    "llama_train_full", "llama_train_shira_dense", "llama_grad_probe",
    "sd_fwd", "sd_train_shira", "sd_train_lora", "sd_train_full",
    "sd_grad_probe", "apply_shira", "fuse_lora", "masked_grad_op",
]


def test_all_artifacts_present(manifest, artifacts_dir):
    for name in EXPECTED:
        assert name in manifest["artifacts"], name
        path = os.path.join(artifacts_dir, manifest["artifacts"][name]["file"])
        assert os.path.getsize(path) > 100, name


def test_hlo_is_text(manifest, artifacts_dir):
    for name in EXPECTED:
        path = os.path.join(artifacts_dir, manifest["artifacts"][name]["file"])
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, name


def test_train_step_io_shapes_match(manifest):
    """theta/m/v inputs and outputs agree in length for every train step."""
    for name, art in manifest["artifacts"].items():
        if "_train_" not in name:
            continue
        ins = {e["name"]: e for e in art["inputs"]}
        outs = {e["name"]: e for e in art["outputs"]}
        K = ins["theta"]["shape"][0]
        for field in ("theta", "m", "v"):
            assert ins[field]["shape"] == [K], (name, field)
        for field in ("theta_out", "m_out", "v_out"):
            assert outs[field]["shape"] == [K], (name, field)
        assert outs["loss"]["shape"] == []


def test_theta_lens_consistent(manifest):
    mm = manifest["models"]["llama"]
    lay = mm["layout"]
    assert mm["theta_len"]["shira"] == sum(e["k"] for e in lay["shira"])
    assert mm["theta_len"]["lora"] == sum(
        e["a_len"] + e["b_len"] for e in lay["lora"])
    assert mm["theta_len"]["dora"] == mm["theta_len"]["lora"] + sum(
        e["mag_len"] for e in lay["dora"])
    # shira offsets are contiguous
    off = 0
    for e in lay["shira"]:
        assert e["off"] == off
        off += e["k"]


def test_sparsity_matches_config(manifest):
    """SHiRA trains ~frac of each target (paper: 1-2%)."""
    frac = manifest["adapter"]["shira_frac"]
    for e in manifest["models"]["llama"]["layout"]["shira"]:
        numel = e["shape"][0] * e["shape"][1]
        assert abs(e["k"] / numel - frac) < 0.5 * frac + 1.0 / numel


def test_shira_changes_far_fewer_params_than_lora(manifest):
    """The %C column of Table 2: fused SHiRA touches ~1-2% of target
    weights; fused LoRA rewrites 100% of them."""
    mm = manifest["models"]["llama"]
    target_numel = sum(e["shape"][0] * e["shape"][1]
                       for e in mm["layout"]["probe"])
    shira_changed = mm["theta_len"]["shira"]
    assert shira_changed / target_numel < 0.05


def test_param_count_orders(manifest):
    """Input ordering: base params come first, in param_spec order."""
    mm = manifest["models"]["llama"]
    art = manifest["artifacts"]["llama_fwd"]
    base_names = [p["name"] for p in mm["params"]]
    got = [e["name"] for e in art["inputs"][:len(base_names)]]
    assert got == base_names


def test_pallas_demo_shapes(manifest):
    d = manifest["pallas_demo"]
    art = manifest["artifacts"]["apply_shira"]
    ins = {e["name"]: e for e in art["inputs"]}
    assert ins["w"]["shape"] == [d["dim"], d["dim"]]
    assert ins["idx"]["shape"] == [d["k"]]
    assert ins["vals"]["shape"] == [d["k"]]
