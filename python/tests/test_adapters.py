"""Adapter machinery: effective-weight builders, gradient equivalences and
the generic Adam train step.

The two load-bearing equivalences for the paper:
  * sparse-leaf gradient == dense gradient gathered at the mask (the
    memory-efficient App.-D formulation computes exactly the App.-C
    gradient-hook update), and
  * fused LoRA forward == unfused LoRA-branch forward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import adapters as A, configs as C, model as M, params as P


CFG, ACFG = C.LLAMA_A, C.ADAPTER


@pytest.fixture(scope="module")
def base():
    return P.init_params(CFG, seed=21)


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(33)
    x = rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)).astype(np.int32)
    y = rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)).astype(np.int32)
    mask = np.zeros((CFG.batch, CFG.seq_len), np.float32)
    mask[:, -1] = 1.0
    return jnp.asarray(x), jnp.asarray(y), jnp.asarray(mask)


def random_mask_idx(seed=0):
    rng = np.random.default_rng(seed)
    lay = P.shira_layout(CFG, ACFG)
    idx = np.concatenate([
        rng.choice(e["shape"][0] * e["shape"][1], e["k"], replace=False)
        for e in lay
    ]).astype(np.int32)
    return lay, jnp.asarray(idx)


def gather_theta(base, lay, idx):
    segs = []
    for e in lay:
        seg = idx[e["off"]:e["off"] + e["k"]]
        segs.append(jnp.asarray(base[e["name"]]).reshape(-1)[seg])
    return jnp.concatenate(segs)


# ---------------------------------------------------------------------------
# Effective-weight builders
# ---------------------------------------------------------------------------

class TestEffectiveShira:
    def test_identity_when_theta_is_base(self, base):
        lay, idx = random_mask_idx(0)
        theta = gather_theta(base, lay, idx)
        eff = A.effective_shira(base, theta, idx, lay)
        for name in base:
            np.testing.assert_array_equal(np.asarray(eff[name]),
                                          np.asarray(base[name]))

    def test_changes_only_masked_entries(self, base):
        lay, idx = random_mask_idx(1)
        theta = gather_theta(base, lay, idx) + 1.0
        eff = A.effective_shira(base, theta, idx, lay)
        for e in lay:
            delta = np.abs(np.asarray(eff[e["name"]]) -
                           np.asarray(base[e["name"]])).reshape(-1)
            changed = np.nonzero(delta > 0)[0]
            want = np.sort(np.asarray(idx[e["off"]:e["off"] + e["k"]]))
            np.testing.assert_array_equal(np.sort(changed), want)
            np.testing.assert_allclose(delta[changed], 1.0, rtol=1e-6)

    def test_non_target_params_untouched(self, base):
        lay, idx = random_mask_idx(2)
        theta = gather_theta(base, lay, idx) + 5.0
        eff = A.effective_shira(base, theta, idx, lay)
        targets = set(CFG.target_names())
        for name in base:
            if name not in targets:
                assert eff[name] is base[name]


class TestEffectiveLora:
    def test_zero_b_is_identity(self, base):
        lay = P.lora_layout(CFG, ACFG)
        K = P.lora_theta_len(CFG, ACFG)
        rng = np.random.default_rng(0)
        theta = np.zeros(K, np.float32)
        for e in lay:  # A random, B zero -> AB = 0
            theta[e["a_off"]:e["a_off"] + e["a_len"]] = rng.normal(
                0, 1, e["a_len"])
        eff = A.effective_lora(base, jnp.asarray(theta), lay, scale=2.0)
        for name in CFG.target_names():
            np.testing.assert_array_equal(np.asarray(eff[name]),
                                          np.asarray(base[name]))

    def test_matches_manual_ab(self, base):
        lay = P.lora_layout(CFG, ACFG)
        K = P.lora_theta_len(CFG, ACFG)
        rng = np.random.default_rng(4)
        theta = rng.normal(0, 0.1, K).astype(np.float32)
        scale = 1.7
        eff = A.effective_lora(base, jnp.asarray(theta), lay, scale=scale)
        e = lay[0]
        n, m, r = e["shape"][0], e["shape"][1], e["r"]
        a = theta[e["a_off"]:e["a_off"] + e["a_len"]].reshape(n, r)
        b = theta[e["b_off"]:e["b_off"] + e["b_len"]].reshape(r, m)
        want = np.asarray(base[e["name"]]) + scale * a @ b
        np.testing.assert_allclose(np.asarray(eff[e["name"]]), want,
                                   rtol=1e-5, atol=1e-6)

    def test_fused_equals_unfused_forward(self, base, batch):
        """Paper Appendix A: fused W+sAB forward == LoRA-branch forward."""
        lay = P.lora_layout(CFG, ACFG)
        K = P.lora_theta_len(CFG, ACFG)
        rng = np.random.default_rng(5)
        theta = jnp.asarray(rng.normal(0, 0.05, K), jnp.float32)
        scale = ACFG.lora_alpha / ACFG.lora_rank
        x, _, _ = batch
        eff = A.effective_lora(base, theta, lay, scale)
        fused = M.llama_fwd(eff, x, CFG)
        branches = A.lora_branches(theta, lay)
        unfused = M.llama_fwd(base, x, CFG, lora_branch=branches,
                              lora_scale=scale)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                                   rtol=2e-3, atol=2e-3)


class TestEffectiveDora:
    def test_column_norms_equal_mag(self, base):
        lay = P.dora_layout(CFG, ACFG)
        K = P.dora_theta_len(CFG, ACFG)
        rng = np.random.default_rng(6)
        theta = np.zeros(K, np.float32)
        for e in lay:
            theta[e["a_off"]:e["a_off"] + e["a_len"]] = rng.normal(
                0, 0.1, e["a_len"])
            theta[e["b_off"]:e["b_off"] + e["b_len"]] = rng.normal(
                0, 0.1, e["b_len"])
            theta[e["mag_off"]:e["mag_off"] + e["mag_len"]] = rng.uniform(
                0.5, 2.0, e["mag_len"])
        eff = A.effective_dora(base, jnp.asarray(theta), lay, scale=0.5)
        e = lay[0]
        w = np.asarray(eff[e["name"]])
        mag = theta[e["mag_off"]:e["mag_off"] + e["mag_len"]]
        np.testing.assert_allclose(np.linalg.norm(w, axis=0), np.abs(mag),
                                   rtol=1e-3)

    def test_identity_at_init(self, base):
        """B=0 and mag=||W||_col reproduces the base weight (DoRA init)."""
        lay = P.dora_layout(CFG, ACFG)
        K = P.dora_theta_len(CFG, ACFG)
        theta = np.zeros(K, np.float32)
        rng = np.random.default_rng(7)
        for e in lay:
            theta[e["a_off"]:e["a_off"] + e["a_len"]] = rng.normal(
                0, 0.1, e["a_len"])
            w = np.asarray(base[e["name"]])
            theta[e["mag_off"]:e["mag_off"] + e["mag_len"]] = np.sqrt(
                (w * w).sum(0) + 1e-6)
        eff = A.effective_dora(base, jnp.asarray(theta), lay, scale=0.5)
        for e in lay:
            np.testing.assert_allclose(np.asarray(eff[e["name"]]),
                                       np.asarray(base[e["name"]]),
                                       rtol=1e-4, atol=1e-5)


class TestEffectiveShiraDora:
    def test_sparse_direction_and_mag(self, base):
        lay = P.shira_dora_layout(CFG, ACFG)
        Ks = P.shira_theta_len(CFG, ACFG)
        K = P.shira_dora_theta_len(CFG, ACFG)
        _, idx = random_mask_idx(8)
        theta = np.zeros(K, np.float32)
        # direction values = base values, mag = column norms -> identity
        segs = gather_theta(base, P.shira_layout(CFG, ACFG), idx)
        theta[:Ks] = np.asarray(segs)
        for e in lay:
            w = np.asarray(base[e["name"]])
            theta[e["mag_off"]:e["mag_off"] + e["mag_len"]] = np.sqrt(
                (w * w).sum(0) + 1e-6)
        eff = A.effective_shira_dora(base, jnp.asarray(theta), idx, lay)
        for e in lay:
            np.testing.assert_allclose(np.asarray(eff[e["name"]]),
                                       np.asarray(base[e["name"]]),
                                       rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Gradient equivalence: sparse leaf == dense-grad gather (App. C == App. D)
# ---------------------------------------------------------------------------

def test_sparse_grad_equals_gathered_dense_grad(base, batch):
    lay, idx = random_mask_idx(9)
    theta = gather_theta(base, lay, idx)
    x, y, mask = batch

    def sparse_obj(th):
        eff = A.effective_shira(base, th, idx, lay)
        return M.llama_loss(eff, x, y, mask, CFG)

    g_sparse = jax.grad(sparse_obj)(theta)

    probe = P.probe_layout(CFG)

    def dense_obj(flat):
        eff = dict(base)
        for e in probe:
            seg = flat[e["off"]:e["off"] + e["len"]]
            eff[e["name"]] = seg.reshape(e["shape"])
        return M.llama_loss(eff, x, y, mask, CFG)

    t0 = jnp.concatenate([jnp.asarray(base[e["name"]]).reshape(-1)
                          for e in probe])
    g_dense = jax.grad(dense_obj)(t0)

    # gather dense grad at the mask indices, per target
    gathered = []
    probe_off = {e["name"]: e["off"] for e in probe}
    for e in lay:
        seg = idx[e["off"]:e["off"] + e["k"]]
        gathered.append(g_dense[probe_off[e["name"]] + seg])
    g_gathered = jnp.concatenate(gathered)
    np.testing.assert_allclose(np.asarray(g_sparse), np.asarray(g_gathered),
                               rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------

class TestAdam:
    def test_zero_grad_no_move(self):
        theta = jnp.asarray([1.0, -2.0])
        t2, m2, v2 = A.adam_update(theta, jnp.zeros(2), jnp.zeros(2),
                                   jnp.zeros(2), jnp.int32(0), jnp.float32(0.1))
        np.testing.assert_array_equal(np.asarray(t2), np.asarray(theta))

    def test_first_step_magnitude_is_lr(self):
        """With bias correction, |Δθ| == lr on step 0 (up to eps)."""
        theta = jnp.zeros(3)
        g = jnp.asarray([1.0, -0.5, 2.0])
        t2, _, _ = A.adam_update(theta, g, jnp.zeros(3), jnp.zeros(3),
                                 jnp.int32(0), jnp.float32(0.01))
        np.testing.assert_allclose(np.abs(np.asarray(t2)), 0.01, rtol=1e-3)

    def test_matches_reference_sequence(self):
        rng = np.random.default_rng(0)
        theta = jnp.asarray(rng.normal(size=5), jnp.float32)
        m = jnp.zeros(5)
        v = jnp.zeros(5)
        ref_t, ref_m, ref_v = np.asarray(theta), np.zeros(5), np.zeros(5)
        lr = 0.02
        for step in range(4):
            g = rng.normal(size=5).astype(np.float32)
            theta, m, v = A.adam_update(theta, jnp.asarray(g), m, v,
                                        jnp.int32(step), jnp.float32(lr))
            ref_m = 0.9 * ref_m + 0.1 * g
            ref_v = 0.999 * ref_v + 0.001 * g * g
            mh = ref_m / (1 - 0.9 ** (step + 1))
            vh = ref_v / (1 - 0.999 ** (step + 1))
            ref_t = ref_t - lr * mh / (np.sqrt(vh) + 1e-8)
        np.testing.assert_allclose(np.asarray(theta), ref_t, rtol=1e-4,
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# Train steps actually learn
# ---------------------------------------------------------------------------

def run_steps(kind, n_steps=8, lr=5e-3, family="llama", seed=50):
    rng = np.random.default_rng(seed)
    if family == "llama":
        cfg = CFG
        base = P.init_params(cfg, seed=21)
        x = rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len)).astype(np.int32)
        y = np.roll(x, -1, axis=1).astype(np.int32)  # learnable: predict shift
        mask = np.ones((cfg.batch, cfg.seq_len), np.float32)
        mask[:, -1] = 0
        data = (jnp.asarray(x), jnp.asarray(y), jnp.asarray(mask))
    else:
        cfg = C.SD
        base = P.init_params(cfg, seed=22)
        z = rng.normal(size=(cfg.batch, cfg.d_z)).astype(np.float32)
        tgt = rng.normal(size=(cfg.batch, cfg.d_img)).astype(np.float32)
        data = (jnp.asarray(z), jnp.asarray(tgt))

    flat = P.flatten_params(base, cfg)
    step_fn = jax.jit(A.make_train_step(family, kind, cfg, ACFG))

    if kind in ("shira", "shira_dora"):
        lay = P.shira_layout(cfg, ACFG)
        idx = np.concatenate([
            rng.choice(e["shape"][0] * e["shape"][1], e["k"], replace=False)
            for e in lay
        ]).astype(np.int32)
        idx = jnp.asarray(idx)
    if kind == "shira":
        theta = gather_theta(base, P.shira_layout(cfg, ACFG), idx) \
            if family == "llama" else jnp.concatenate([
                jnp.asarray(base[e["name"]]).reshape(-1)[
                    idx[e["off"]:e["off"] + e["k"]]]
                for e in P.shira_layout(cfg, ACFG)])
    elif kind == "lora":
        lay = P.lora_layout(cfg, ACFG)
        K = P.lora_theta_len(cfg, ACFG)
        th = np.zeros(K, np.float32)
        for e in lay:
            th[e["a_off"]:e["a_off"] + e["a_len"]] = rng.normal(
                0, 0.02, e["a_len"])
        theta = jnp.asarray(th)
    elif kind == "dora":
        lay = P.dora_layout(cfg, ACFG)
        K = P.dora_theta_len(cfg, ACFG)
        th = np.zeros(K, np.float32)
        for e in lay:
            th[e["a_off"]:e["a_off"] + e["a_len"]] = rng.normal(
                0, 0.02, e["a_len"])
            w = np.asarray(base[e["name"]])
            th[e["mag_off"]:e["mag_off"] + e["mag_len"]] = np.sqrt(
                (w * w).sum(0) + 1e-6)
        theta = jnp.asarray(th)
    elif kind == "shira_dora":
        lay = P.shira_dora_layout(cfg, ACFG)
        K = P.shira_dora_theta_len(cfg, ACFG)
        th = np.zeros(K, np.float32)
        th[:P.shira_theta_len(cfg, ACFG)] = np.asarray(
            gather_theta(base, P.shira_layout(cfg, ACFG), idx))
        for e in lay:
            w = np.asarray(base[e["name"]])
            th[e["mag_off"]:e["mag_off"] + e["mag_len"]] = np.sqrt(
                (w * w).sum(0) + 1e-6)
        theta = jnp.asarray(th)
    elif kind == "full":
        theta = jnp.concatenate([jnp.asarray(t).reshape(-1) for t in flat])

    K = theta.shape[0]
    m = jnp.zeros(K)
    v = jnp.zeros(K)
    losses = []
    for s in range(n_steps):
        args = list(flat) if kind != "full" else []
        args += [theta, m, v]
        if kind in ("shira", "shira_dora"):
            args.append(idx)
        args += [jnp.int32(s), jnp.float32(lr)]
        args += list(data)
        theta, m, v, loss = step_fn(*args)
        losses.append(float(loss))
    return losses


@pytest.mark.parametrize("kind", ["shira", "lora", "dora", "shira_dora", "full"])
def test_llama_train_step_reduces_loss(kind):
    losses = run_steps(kind)
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("kind", ["shira", "lora", "full"])
def test_sd_train_step_reduces_loss(kind):
    losses = run_steps(kind, family="sd", lr=1e-2)
    assert losses[-1] < losses[0], losses
