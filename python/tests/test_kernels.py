"""L1 Pallas kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps shapes / sparsity / block sizes; every property asserts
bit-compatible (or allclose within f32 matmul tolerance) agreement between
the tiled kernel and the reference.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    lora_fuse,
    masked_grad,
    partition_updates,
    pick_block_rows,
    pick_tiles,
    scatter_update,
    scatter_update_flat,
)
from compile.kernels.ref import (
    gather_ref,
    lora_fuse_ref,
    masked_grad_ref,
    scatter_update_ref,
)

SETTINGS = dict(max_examples=20, deadline=None)


def make_case(rng, n, m, k):
    w = rng.normal(size=(n, m)).astype(np.float32)
    idx = rng.choice(n * m, size=k, replace=False).astype(np.int32)
    vals = rng.normal(size=k).astype(np.float32)
    return w, idx, vals


# ---------------------------------------------------------------------------
# scatter_update (tiled, host-partitioned)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.sampled_from([8, 16, 32, 64, 128]),
    m=st.sampled_from([8, 32, 64, 128]),
    frac=st.floats(0.005, 0.2),
    seed=st.integers(0, 2**16),
)
def test_scatter_tiled_matches_ref(n, m, frac, seed):
    rng = np.random.default_rng(seed)
    k = max(1, int(frac * n * m))
    w, idx, vals = make_case(rng, n, m, k)
    br = pick_block_rows(n, m, vmem_budget_bytes=4 * m * max(1, n // 4))
    ti, tv = partition_updates(idx, vals, n, m, br)
    out = scatter_update(jnp.asarray(w), jnp.asarray(ti), jnp.asarray(tv),
                         block_rows=br)
    ref = scatter_update_ref(jnp.asarray(w), jnp.asarray(idx), jnp.asarray(vals))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), br=st.sampled_from([1, 2, 4, 8, 16, 32]))
def test_scatter_tiled_all_block_sizes(seed, br):
    rng = np.random.default_rng(seed)
    n, m = 32, 16
    w, idx, vals = make_case(rng, n, m, 50)
    ti, tv = partition_updates(idx, vals, n, m, br)
    out = scatter_update(jnp.asarray(w), jnp.asarray(ti), jnp.asarray(tv),
                         block_rows=br)
    ref = scatter_update_ref(jnp.asarray(w), jnp.asarray(idx), jnp.asarray(vals))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_scatter_single_update():
    w = np.zeros((8, 8), np.float32)
    ti, tv = partition_updates(np.array([13]), np.array([7.0]), 8, 8, 4)
    out = scatter_update(jnp.asarray(w), jnp.asarray(ti), jnp.asarray(tv),
                         block_rows=4)
    assert out[1, 5] == 7.0
    assert float(jnp.sum(jnp.abs(out))) == 7.0


def test_scatter_empty_update_stream():
    w = np.random.default_rng(0).normal(size=(8, 8)).astype(np.float32)
    ti, tv = partition_updates(np.array([], np.int64), np.array([], np.float32),
                               8, 8, 4)
    out = scatter_update(jnp.asarray(w), jnp.asarray(ti), jnp.asarray(tv),
                         block_rows=4)
    np.testing.assert_array_equal(np.asarray(out), w)


def test_scatter_full_overwrite():
    """k = n*m degenerates to a full dense copy."""
    rng = np.random.default_rng(3)
    n, m = 16, 8
    w = rng.normal(size=(n, m)).astype(np.float32)
    idx = np.arange(n * m)
    vals = rng.normal(size=n * m).astype(np.float32)
    ti, tv = partition_updates(idx, vals, n, m, 4)
    out = scatter_update(jnp.asarray(w), jnp.asarray(ti), jnp.asarray(tv),
                         block_rows=4)
    np.testing.assert_array_equal(np.asarray(out), vals.reshape(n, m))


def test_partition_updates_preserves_every_update():
    rng = np.random.default_rng(1)
    n, m, br = 64, 32, 8
    _, idx, vals = make_case(rng, n, m, 100)
    ti, tv = partition_updates(idx, vals, n, m, br)
    got = {}
    for t in range(ti.shape[0]):
        for j in range(ti.shape[1]):
            if ti[t, j] != br * m:
                got[t * br * m + int(ti[t, j])] = float(tv[t, j])
    want = dict(zip(idx.tolist(), vals.tolist()))
    assert got == pytest.approx(want)


def test_partition_pad_index_is_oob():
    ti, tv = partition_updates(np.array([0]), np.array([1.0]), 8, 8, 2)
    assert ti.max() <= 2 * 8  # pad index == block_rows * m
    assert (ti >= 0).all()


# ---------------------------------------------------------------------------
# scatter_update_flat (runtime indices, used by the apply_shira artifact)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.sampled_from([16, 32, 64]),
    m=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**16),
)
def test_scatter_flat_matches_ref(n, m, seed):
    rng = np.random.default_rng(seed)
    k = max(1, (n * m) // 50)
    w, idx, vals = make_case(rng, n, m, k)
    out = scatter_update_flat(jnp.asarray(w), jnp.asarray(idx),
                              jnp.asarray(vals))
    ref = scatter_update_ref(jnp.asarray(w), jnp.asarray(idx), jnp.asarray(vals))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_scatter_flat_respects_block_rows():
    rng = np.random.default_rng(7)
    w, idx, vals = make_case(rng, 32, 32, 20)
    for br in (2, 8, 16, 32):
        out = scatter_update_flat(jnp.asarray(w), jnp.asarray(idx),
                                  jnp.asarray(vals), block_rows=br)
        ref = scatter_update_ref(jnp.asarray(w), jnp.asarray(idx),
                                 jnp.asarray(vals))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# lora_fuse
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.sampled_from([16, 32, 64, 128]),
    m=st.sampled_from([16, 64, 128]),
    r=st.sampled_from([1, 2, 4, 8]),
    scale=st.floats(-2.0, 2.0),
    seed=st.integers(0, 2**16),
)
def test_lora_fuse_matches_ref(n, m, r, scale, seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(n, m)).astype(np.float32)
    a = rng.normal(size=(n, r)).astype(np.float32)
    b = rng.normal(size=(r, m)).astype(np.float32)
    s = np.array([[scale]], np.float32)
    out = lora_fuse(jnp.asarray(w), jnp.asarray(a), jnp.asarray(b),
                    jnp.asarray(s))
    ref = lora_fuse_ref(w, a, b, np.float32(scale))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-5)


def test_lora_fuse_zero_scale_is_identity():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(32, 32)).astype(np.float32)
    a = rng.normal(size=(32, 4)).astype(np.float32)
    b = rng.normal(size=(4, 32)).astype(np.float32)
    out = lora_fuse(jnp.asarray(w), jnp.asarray(a), jnp.asarray(b),
                    jnp.zeros((1, 1), jnp.float32))
    np.testing.assert_array_equal(np.asarray(out), w)


def test_lora_fuse_explicit_tiles():
    rng = np.random.default_rng(5)
    w = rng.normal(size=(64, 48)).astype(np.float32)
    a = rng.normal(size=(64, 4)).astype(np.float32)
    b = rng.normal(size=(4, 48)).astype(np.float32)
    s = np.ones((1, 1), np.float32)
    for bm, bn in [(8, 8), (16, 48), (64, 16), (32, 24)]:
        out = lora_fuse(jnp.asarray(w), jnp.asarray(a), jnp.asarray(b),
                        jnp.asarray(s), bm=bm, bn=bn)
        np.testing.assert_allclose(np.asarray(out), lora_fuse_ref(w, a, b, 1.0),
                                   rtol=1e-5, atol=1e-5)


def test_pick_tiles_divides():
    for n, m in [(100, 60), (4096, 4096), (7, 13), (256, 512)]:
        bm, bn = pick_tiles(n, m)
        assert n % bm == 0 and m % bn == 0
        assert 1 <= bm <= n and 1 <= bn <= m


# ---------------------------------------------------------------------------
# masked_grad
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.sampled_from([8, 32, 64, 128]),
    m=st.sampled_from([16, 64, 128]),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
)
def test_masked_grad_matches_ref(n, m, density, seed):
    rng = np.random.default_rng(seed)
    g = rng.normal(size=(n, m)).astype(np.float32)
    mask = (rng.random((n, m)) < density).astype(np.float32)
    out = masked_grad(jnp.asarray(g), jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(out), masked_grad_ref(g, mask))


def test_masked_grad_all_zero_mask():
    g = np.ones((16, 16), np.float32)
    out = masked_grad(jnp.asarray(g), jnp.zeros((16, 16), jnp.float32))
    assert float(jnp.sum(jnp.abs(out))) == 0.0


def test_masked_grad_identity_mask():
    rng = np.random.default_rng(2)
    g = rng.normal(size=(16, 16)).astype(np.float32)
    out = masked_grad(jnp.asarray(g), jnp.ones((16, 16), jnp.float32))
    np.testing.assert_array_equal(np.asarray(out), g)


# ---------------------------------------------------------------------------
# pick_block_rows
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(n=st.integers(1, 512), m=st.integers(1, 512))
def test_pick_block_rows_divides_and_fits(n, m):
    br = pick_block_rows(n, m)
    assert 1 <= br <= n
    assert n % br == 0
    if br > 1:  # fits the default VMEM budget unless a single row overflows it
        assert br * m * 4 <= 4 * 1024 * 1024


def test_gather_ref_roundtrip():
    """gather(scatter(w, idx, v), idx) == v — adapter extract/apply inverse."""
    rng = np.random.default_rng(9)
    w, idx, vals = make_case(rng, 32, 32, 64)
    w2 = scatter_update_ref(jnp.asarray(w), jnp.asarray(idx), jnp.asarray(vals))
    got = gather_ref(w2, jnp.asarray(idx))
    np.testing.assert_array_equal(np.asarray(got), vals)
