"""L2 model graphs: shapes, causality, loss-masking and determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs as C, model as M, params as P


@pytest.fixture(scope="module")
def llama():
    cfg = C.LLAMA_A
    return cfg, P.init_params(cfg, seed=11)


@pytest.fixture(scope="module")
def sd():
    cfg = C.SD
    return cfg, P.init_params(cfg, seed=12)


def batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len)).astype(np.int32)
    y = rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len)).astype(np.int32)
    mask = np.zeros((cfg.batch, cfg.seq_len), np.float32)
    mask[:, -1] = 1.0
    return jnp.asarray(x), jnp.asarray(y), jnp.asarray(mask)


class TestLlamaForward:
    def test_logit_shape(self, llama):
        cfg, p = llama
        x, _, _ = batch(cfg)
        logits = M.llama_fwd(p, x, cfg)
        assert logits.shape == (cfg.batch, cfg.seq_len, cfg.vocab)

    def test_deterministic(self, llama):
        cfg, p = llama
        x, _, _ = batch(cfg)
        l1 = M.llama_fwd(p, x, cfg)
        l2 = M.llama_fwd(p, x, cfg)
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))

    def test_causality(self, llama):
        """Changing token t must not change logits at positions < t."""
        cfg, p = llama
        x, _, _ = batch(cfg)
        t = cfg.seq_len // 2
        x2 = x.at[:, t:].set((x[:, t:] + 1) % cfg.vocab)
        l1 = np.asarray(M.llama_fwd(p, x, cfg))
        l2 = np.asarray(M.llama_fwd(p, x2, cfg))
        np.testing.assert_array_equal(l1[:, :t], l2[:, :t])
        assert np.abs(l1[:, t:] - l2[:, t:]).max() > 0

    def test_finite(self, llama):
        cfg, p = llama
        x, _, _ = batch(cfg)
        assert bool(jnp.all(jnp.isfinite(M.llama_fwd(p, x, cfg))))

    def test_batch_independence(self, llama):
        """Row b of the batch depends only on row b of the tokens."""
        cfg, p = llama
        x, _, _ = batch(cfg)
        x2 = x.at[1:].set((x[1:] + 3) % cfg.vocab)
        l1 = np.asarray(M.llama_fwd(p, x, cfg))
        l2 = np.asarray(M.llama_fwd(p, x2, cfg))
        np.testing.assert_array_equal(l1[0], l2[0])


class TestLlamaLoss:
    def test_loss_positive_scalar(self, llama):
        cfg, p = llama
        x, y, mask = batch(cfg)
        loss = M.llama_loss(p, x, y, mask, cfg)
        assert loss.shape == ()
        assert float(loss) > 0

    def test_mask_selects_positions(self, llama):
        """Loss with answer-only mask ignores target values elsewhere."""
        cfg, p = llama
        x, y, mask = batch(cfg)
        y2 = y.at[:, :-1].set((y[:, :-1] + 7) % cfg.vocab)
        l1 = float(M.llama_loss(p, x, y, mask, cfg))
        l2 = float(M.llama_loss(p, x, y2, mask, cfg))
        assert l1 == pytest.approx(l2, rel=1e-6)

    def test_uniform_model_loss_near_log_vocab(self):
        """A zeroed model predicts ~uniform -> CE ~= log(V)."""
        cfg = C.LLAMA_A
        p = {k: jnp.zeros_like(v) for k, v in P.init_params(cfg, 0).items()}
        x, y, mask = batch(cfg)
        loss = float(M.llama_loss(p, x, y, mask, cfg))
        assert loss == pytest.approx(np.log(cfg.vocab), rel=1e-3)

    def test_all_zero_mask_is_safe(self, llama):
        cfg, p = llama
        x, y, _ = batch(cfg)
        loss = M.llama_loss(p, x, y, jnp.zeros_like(x, jnp.float32), cfg)
        assert np.isfinite(float(loss))


class TestRmsnorm:
    def test_unit_norm(self):
        x = np.random.default_rng(0).normal(size=(4, 16)).astype(np.float32)
        y = M.rmsnorm(jnp.asarray(x), jnp.ones(16, jnp.float32))
        rms = np.sqrt(np.mean(np.asarray(y) ** 2, axis=-1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-4)

    def test_gain_scales(self):
        x = np.random.default_rng(1).normal(size=(4, 16)).astype(np.float32)
        y1 = M.rmsnorm(jnp.asarray(x), jnp.ones(16, jnp.float32))
        y2 = M.rmsnorm(jnp.asarray(x), 3.0 * jnp.ones(16, jnp.float32))
        np.testing.assert_allclose(np.asarray(y2), 3 * np.asarray(y1), rtol=1e-5)


class TestSd:
    def test_shapes(self, sd):
        cfg, p = sd
        z = jnp.ones((cfg.batch, cfg.d_z), jnp.float32)
        img = M.sd_fwd(p, z, cfg)
        assert img.shape == (cfg.batch, cfg.d_img)

    def test_content_sensitivity(self, sd):
        """Different content latents must map to different images."""
        cfg, p = sd
        rng = np.random.default_rng(0)
        z1 = jnp.asarray(rng.normal(size=(cfg.batch, cfg.d_z)), jnp.float32)
        z2 = jnp.asarray(rng.normal(size=(cfg.batch, cfg.d_z)), jnp.float32)
        i1, i2 = M.sd_fwd(p, z1, cfg), M.sd_fwd(p, z2, cfg)
        assert float(jnp.mean(jnp.abs(i1 - i2))) > 1e-3

    def test_mse_loss_zero_on_self(self, sd):
        cfg, p = sd
        z = jnp.ones((cfg.batch, cfg.d_z), jnp.float32)
        img = M.sd_fwd(p, z, cfg)
        assert float(M.sd_loss(p, z, img, cfg)) == 0.0


class TestParams:
    def test_flatten_roundtrip(self, llama):
        cfg, p = llama
        flat = P.flatten_params(p, cfg)
        p2 = P.unflatten_params(flat, cfg)
        assert set(p2) == set(p)
        for k in p:
            np.testing.assert_array_equal(np.asarray(p[k]), np.asarray(p2[k]))

    def test_param_spec_order_stable(self):
        cfg = C.LLAMA_A
        assert cfg.param_spec() == cfg.param_spec()
        names = [n for n, _ in cfg.param_spec()]
        assert names[0] == "embed" and names[-1] == "head"
        assert len(names) == len(set(names))

    def test_init_seed_determinism(self):
        cfg = C.LLAMA_A
        p1 = P.init_params(cfg, 5)
        p2 = P.init_params(cfg, 5)
        p3 = P.init_params(cfg, 6)
        np.testing.assert_array_equal(np.asarray(p1["embed"]),
                                      np.asarray(p2["embed"]))
        assert np.abs(np.asarray(p1["embed"]) - np.asarray(p3["embed"])).max() > 0

    def test_norm_gains_init_to_one(self):
        cfg = C.LLAMA_A
        p = P.init_params(cfg, 0)
        np.testing.assert_array_equal(np.asarray(p["lnf"]),
                                      np.ones(cfg.d_model, np.float32))

    def test_target_names_subset_of_params(self):
        for cfg in (C.LLAMA_A, C.SD):
            names = {n for n, _ in cfg.param_spec()}
            assert set(cfg.target_names()) <= names
